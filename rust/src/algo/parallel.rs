//! Threaded variants of the three solvers (paper §4.1.2).
//!
//! The matrix is split into contiguous row blocks, one per thread — "which
//! makes the most sense since all computations are done in row order"
//! (§4.1.2). Each MAP-UOT thread runs the same fused double-loop over its
//! block with a *private* `NextSum_col` (Algorithm 1 lines 5–15); the main
//! thread reduces the per-thread sums (lines 16–20). Private, separately
//! allocated accumulators + 64-byte-aligned row blocks are what make the
//! false-sharing figure (Fig. 12) flat.
//!
//! std::thread::scope plays the role of Pthreads create/join. POT's four
//! sweeps and COFFEE's two phases need a barrier between sweeps, realized
//! as one scope per sweep group — this extra synchronization is part of
//! what Fig. 10 measures.
//!
//! Every solver comes in three forms: `*_iterate_into` (caller-provided
//! scratch — the allocation-free workspace path), `*_iterate_tracked`
//! (additionally returns the iteration's max element change, folded into
//! the sweep), and the legacy `*_iterate` wrappers that allocate their own
//! scratch per call. The per-thread `NextSum_col` blocks arrive as
//! `acc: &mut [Vec<f32>]` — still separately allocated vectors, so no two
//! threads ever share a cache line of accumulator state.

// The workspace variants take each scratch buffer explicitly — that is the
// point of the allocation-free contract, not an accident of design.
#![allow(clippy::too_many_arguments)]

use std::thread;

use crate::algo::mapuot::{
    fused_rows, fused_rows_tracked, scale_by_scalar_and_accumulate_tracked, scale_by_vec_and_sum,
};
use crate::algo::scaling::{factor, factors_into, recip_into};
use crate::util::Matrix;

/// Clamp a thread-count request to something usable.
pub fn effective_threads(requested: usize, rows: usize) -> usize {
    requested.max(1).min(rows.max(1))
}

/// Row-block partition for `m` rows over `threads` workers capped by the
/// number of per-thread accumulators: `(rows_per_block, blocks_used)`.
fn partition(m: usize, threads: usize, acc_len: usize) -> (usize, usize) {
    let t = effective_threads(threads, m).min(acc_len.max(1));
    let rows_per = m.div_ceil(t);
    (rows_per, m.div_ceil(rows_per))
}

/// Reduce the first `used` per-thread accumulators into `colsum`
/// (Algorithm 1 lines 16–20, main thread).
fn reduce_acc(colsum: &mut [f32], acc: &[Vec<f32>], used: usize) {
    colsum.fill(0.0);
    for local in &acc[..used] {
        for (s, &v) in colsum.iter_mut().zip(local.iter()) {
            *s += v;
        }
    }
}

/// Parallel column sums of `plan` into `out`, using `acc` for the
/// per-thread partials.
fn par_col_sums_into(plan: &Matrix, rows_per: usize, out: &mut [f32], acc: &mut [Vec<f32>]) {
    let n = plan.cols();
    thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_slice()
            .chunks(rows_per * n)
            .zip(acc.iter_mut())
            .map(|(block, local)| {
                s.spawn(move || {
                    local.fill(0.0);
                    for row in block.chunks_exact(n) {
                        for (sl, &v) in local.iter_mut().zip(row) {
                            *sl += v;
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    let used = plan.rows().div_ceil(rows_per);
    reduce_acc(out, acc, used);
}

/// One parallel MAP-UOT iteration out of caller-provided scratch:
/// `fcol` (length N) and the per-thread `NextSum_col` blocks `acc`.
pub fn mapuot_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    acc: &mut [Vec<f32>],
) {
    let (m, n) = (plan.rows(), plan.cols());
    let (rows_per, used) = partition(m, threads, acc.len());
    factors_into(fcol, cpd, colsum, fi);

    let fcol_ref: &[f32] = fcol;
    thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .zip(rpd.chunks(rows_per))
            .zip(acc.iter_mut())
            .map(|((block, rpd_block), local)| {
                s.spawn(move || {
                    local.fill(0.0);
                    fused_rows(block, n, rpd_block, fcol_ref, fi, local);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    reduce_acc(colsum, acc, used);
}

/// [`mapuot_iterate_into`] with in-sweep delta tracking; returns the
/// iteration's max element change across all row blocks.
pub fn mapuot_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut [Vec<f32>],
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let (rows_per, used) = partition(m, threads, acc.len());
    factors_into(fcol, cpd, colsum, fi);
    recip_into(inv_fcol, fcol);

    let fcol_ref: &[f32] = fcol;
    let inv_ref: &[f32] = inv_fcol;
    let mut delta = 0f32;
    thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .zip(rpd.chunks(rows_per))
            .zip(acc.iter_mut())
            .map(|((block, rpd_block), local)| {
                s.spawn(move || {
                    local.fill(0.0);
                    fused_rows_tracked(block, n, rpd_block, fcol_ref, inv_ref, fi, local)
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, used);
    delta
}

/// One parallel MAP-UOT iteration with `threads` workers; allocates its own
/// scratch per call — prefer [`mapuot_iterate_into`] on hot paths.
pub fn mapuot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut acc: Vec<Vec<f32>> = (0..t).map(|_| vec![0f32; n]).collect();
    mapuot_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut acc);
}

/// One parallel COFFEE iteration (two phase-sweeps with a barrier between)
/// out of caller-provided scratch.
pub fn coffee_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) {
    coffee_phases(plan, colsum, rpd, cpd, fi, threads, fcol, None, rowsum, acc);
}

/// [`coffee_iterate_into`] with in-sweep delta tracking.
pub fn coffee_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) -> f32 {
    coffee_phases(plan, colsum, rpd, cpd, fi, threads, fcol, Some(inv_fcol), rowsum, acc)
}

/// Shared body of the parallel COFFEE iteration; tracks deltas in phase B
/// when `inv_fcol` is provided (same pattern as [`pot_sweeps`]).
fn coffee_phases(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let (rows_per, used) = partition(m, threads, acc.len());
    factors_into(fcol, cpd, colsum, fi);
    let inv_fcol: Option<&[f32]> = match inv_fcol {
        Some(inv) => {
            recip_into(inv, fcol);
            Some(inv)
        }
        None => None,
    };

    // Phase A: column rescale + row sums.
    let fcol_ref: &[f32] = fcol;
    thread::scope(|s| {
        for (block, rs_block) in plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .zip(rowsum.chunks_mut(rows_per))
        {
            s.spawn(move || {
                for (row, rs) in block.chunks_exact_mut(n).zip(rs_block.iter_mut()) {
                    *rs = scale_by_vec_and_sum(row, fcol_ref);
                }
            });
        }
    });

    // Phase B: row rescale + next column sums (tracked when the reciprocal
    // factors are given).
    let rowsum_ref: &[f32] = rowsum;
    let mut delta = 0f32;
    thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .enumerate()
            .zip(acc.iter_mut())
            .map(|((b, block), local)| {
                s.spawn(move || {
                    local.fill(0.0);
                    let mut block_delta = 0f32;
                    for (i, row) in block.chunks_exact_mut(n).enumerate() {
                        let gi = b * rows_per + i;
                        let fr = factor(rpd[gi], rowsum_ref[gi], fi);
                        match inv_fcol {
                            Some(inv) => {
                                block_delta = block_delta.max(
                                    scale_by_scalar_and_accumulate_tracked(row, fr, inv, local),
                                );
                            }
                            None => {
                                for (v, sl) in row.iter_mut().zip(local.iter_mut()) {
                                    *v *= fr;
                                    *sl += *v;
                                }
                            }
                        }
                    }
                    block_delta
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, used);
    delta
}

/// One parallel COFFEE iteration; allocates its own scratch per call —
/// prefer [`coffee_iterate_into`] on hot paths.
pub fn coffee_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut rowsum = vec![0f32; m];
    let mut acc: Vec<Vec<f32>> = (0..t).map(|_| vec![0f32; n]).collect();
    coffee_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut rowsum, &mut acc);
}

/// One parallel POT iteration (four sweeps, each row-partitioned, with
/// barriers between — the NumPy execution model under a parallel BLAS-style
/// backend) out of caller-provided scratch.
pub fn pot_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) {
    pot_sweeps(plan, colsum, rpd, cpd, fi, threads, fcol, None, rowsum, acc);
}

/// [`pot_iterate_into`] with in-sweep delta tracking.
pub fn pot_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) -> f32 {
    pot_sweeps(plan, colsum, rpd, cpd, fi, threads, fcol, Some(inv_fcol), rowsum, acc)
}

/// Shared body of the parallel POT iteration; tracks deltas in sweep 4
/// when `inv_fcol` is provided.
#[allow(clippy::too_many_arguments)]
fn pot_sweeps(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut [Vec<f32>],
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let (rows_per, _) = partition(m, threads, acc.len());

    // Sweep 1: column sums.
    par_col_sums_into(plan, rows_per, colsum, acc);
    factors_into(fcol, cpd, colsum, fi);
    let inv_fcol: Option<&[f32]> = match inv_fcol {
        Some(inv) => {
            recip_into(inv, fcol);
            Some(inv)
        }
        None => None,
    };

    // Sweep 2: column rescale.
    let fcol_ref: &[f32] = fcol;
    thread::scope(|s| {
        for block in plan.as_mut_slice().chunks_mut(rows_per * n) {
            s.spawn(move || {
                for row in block.chunks_exact_mut(n) {
                    for (v, &f) in row.iter_mut().zip(fcol_ref) {
                        *v *= f;
                    }
                }
            });
        }
    });

    // Sweep 3: row sums.
    thread::scope(|s| {
        for (block, rs_block) in plan
            .as_slice()
            .chunks(rows_per * n)
            .zip(rowsum.chunks_mut(rows_per))
        {
            s.spawn(move || {
                for (row, rs) in block.chunks_exact(n).zip(rs_block.iter_mut()) {
                    *rs = row.iter().sum::<f32>();
                }
            });
        }
    });

    // Sweep 4: row rescale (tracked when the reciprocal factors are given).
    let rowsum_ref: &[f32] = rowsum;
    let mut delta = 0f32;
    thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .enumerate()
            .map(|(b, block)| {
                s.spawn(move || {
                    let mut block_delta = 0f32;
                    for (i, row) in block.chunks_exact_mut(n).enumerate() {
                        let gi = b * rows_per + i;
                        let fr = factor(rpd[gi], rowsum_ref[gi], fi);
                        match inv_fcol {
                            Some(inv) => {
                                for (v, &iv) in row.iter_mut().zip(inv) {
                                    let old = *v * iv;
                                    *v *= fr;
                                    block_delta = block_delta.max((*v - old).abs());
                                }
                            }
                            None => {
                                for v in row {
                                    *v *= fr;
                                }
                            }
                        }
                    }
                    block_delta
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });

    // Refresh carried colsum (POT recomputes it next iteration anyway).
    par_col_sums_into(plan, rows_per, colsum, acc);
    delta
}

/// One parallel POT iteration; allocates its own scratch per call —
/// prefer [`pot_iterate_into`] on hot paths.
pub fn pot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut rowsum = vec![0f32; m];
    let mut acc: Vec<Vec<f32>> = (0..t).map(|_| vec![0f32; n]).collect();
    pot_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut rowsum, &mut acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{mapuot, problem::Problem};

    fn check_parallel_matches_serial(
        par: impl Fn(&mut Matrix, &mut [f32], &[f32], &[f32], f32, usize),
        threads: usize,
        seed: u64,
    ) {
        let p = Problem::random(23, 17, 0.7, seed);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        for _ in 0..5 {
            par(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, threads);
        }
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..5 {
            mapuot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
        }
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3, "threads={threads}");
    }

    #[test]
    fn mapuot_parallel_matches_serial() {
        for t in [1, 2, 3, 4, 8, 32] {
            check_parallel_matches_serial(mapuot_iterate, t, 1);
        }
    }

    #[test]
    fn coffee_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(coffee_iterate, t, 2);
        }
    }

    #[test]
    fn pot_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(pot_iterate, t, 3);
        }
    }

    #[test]
    fn more_threads_than_rows_is_safe() {
        let p = Problem::random(3, 5, 0.5, 4);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        mapuot_iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi, 64);
        assert!(a.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(0, 10), 1);
        assert_eq!(effective_threads(16, 4), 4);
        assert_eq!(effective_threads(8, 100), 8);
    }

    #[test]
    fn into_variants_reuse_scratch_across_iterations() {
        let p = Problem::random(19, 13, 0.6, 5);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        let mut fcol = vec![0f32; 13];
        let mut rowsum = vec![0f32; 19];
        let mut acc: Vec<Vec<f32>> = (0..3).map(|_| vec![0f32; 13]).collect();
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..4 {
            coffee_iterate_into(
                &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, 3, &mut fcol, &mut rowsum, &mut acc,
            );
            coffee_iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, 3);
        }
        assert_eq!(a.as_slice(), b.as_slice());
        assert_eq!(cs_a, cs_b);
    }
}
