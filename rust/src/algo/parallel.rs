//! Threaded variants of the three solvers (paper §4.1.2), on two backends.
//!
//! The matrix is split into contiguous row blocks, one per thread — "which
//! makes the most sense since all computations are done in row order"
//! (§4.1.2). Each MAP-UOT thread runs the same fused double-loop over its
//! block with a *private* `NextSum_col` (Algorithm 1 lines 5–15), and the
//! per-thread partials are reduced into the carried column sums (lines
//! 16–20). Blocks are balanced ([`Partition`]): every thread gets
//! `floor(m/t)` or `ceil(m/t)` rows, never a near-empty straggler.
//!
//! Two execution engines drive the same block kernels
//! ([`crate::algo::pool::ParallelBackend`]):
//!
//! * **Pool** (default) — a persistent [`ThreadPool`]: workers are created
//!   once, parked between dispatches, and synchronized by an epoch
//!   barrier. POT's four sweeps and COFFEE's two phases become one epoch
//!   wait each instead of a scope teardown, and the whole iteration is
//!   spawn-free and allocation-free. The `NextSum_col` partials live in a
//!   cache-line-padded [`AccArena`] and the final reduction
//!   (`reduce_acc_pool`) is column-parallel on the same pool.
//! * **SpawnPerIter** (legacy) — `std::thread::scope` create/join per
//!   sweep group, kept so the `fig12` bench can measure the dispatch
//!   overhead head-to-head.
//!
//! Both backends share [`Partition`], the block kernels, and the
//! block-ascending reduction order, so for identical inputs they produce
//! **bit-identical** plans, column sums and tracked deltas (property-tested
//! in `rust/tests/prop_pool.rs`). The column-parallel reduction keeps each
//! column's partial sums in ascending block order — a pairwise tree would
//! round differently and break that contract.
//!
//! Every solver comes as `*_iterate_into` / `*_iterate_tracked` (scope
//! backend, caller-provided scratch), `*_iterate_pool` /
//! `*_iterate_pool_tracked` (pool backend), and the legacy `*_iterate`
//! wrappers that allocate their own scratch per call.

// The workspace variants take each scratch buffer explicitly — that is the
// point of the allocation-free contract, not an accident of design.
#![allow(clippy::too_many_arguments)]

use std::thread;

use crate::algo::kernels::KernelPolicy;
use crate::algo::matfree::{matfree_rows_opt, matfree_seed_rows, GeomProblem};
use crate::algo::mapuot::{
    fused_rows_opt, scale_by_scalar_and_accumulate_tracked, scale_by_vec_and_sum,
};
use crate::algo::pool::{AccArena, PaddedSlots, Partition, SliceRef, ThreadPool};
use crate::algo::scaling::{factor, factors_into, recip_into};
use crate::algo::sparse::{fused_csr_rows, CsrMatrix, NnzPartition};
use crate::util::telemetry;
use crate::util::telemetry::Phase;
use crate::util::Matrix;

/// Clamp a thread-count request to something usable.
pub fn effective_threads(requested: usize, rows: usize) -> usize {
    requested.max(1).min(rows.max(1))
}

/// Columns below which the post-sweep reduction stays on the dispatching
/// thread: one epoch of pool dispatch costs more than summing a few
/// hundred floats per accumulator.
const PAR_REDUCE_MIN_COLS: usize = 1024;

/// Reduce the first `used` accumulators into `colsum` (Algorithm 1 lines
/// 16–20) on the calling thread, in ascending block order.
fn reduce_acc(colsum: &mut [f32], acc: &AccArena, used: usize) {
    let _red = telemetry::span(Phase::Reduction);
    colsum.fill(0.0);
    for b in 0..used {
        for (s, &v) in colsum.iter_mut().zip(acc.row(b)) {
            *s += v;
        }
    }
}

/// Column-parallel reduction on the pool: part `k` owns a contiguous
/// column segment and sums it across accumulators in ascending block
/// order — bit-identical to [`reduce_acc`], just split by column.
fn reduce_acc_pool(colsum: &mut [f32], acc: &AccArena, used: usize, pool: &ThreadPool) {
    let n = colsum.len();
    if pool.threads() <= 1 || used <= 1 || n < PAR_REDUCE_MIN_COLS {
        reduce_acc(colsum, acc, used);
        return;
    }
    let _red = telemetry::span(Phase::Reduction);
    let cols = Partition::new(n, pool.threads(), usize::MAX);
    let out = SliceRef::new(colsum);
    pool.set_reduction_hint(true);
    pool.run(cols.blocks(), |k| {
        let r = cols.range(k);
        // SAFETY: column segments are pairwise disjoint.
        let seg = unsafe { out.range_mut(r.start, r.end) };
        seg.fill(0.0);
        for b in 0..used {
            for (s, &v) in seg.iter_mut().zip(&acc.row(b)[r.start..r.end]) {
                *s += v;
            }
        }
    });
    pool.set_reduction_hint(false);
}

/// Parallel column sums of `plan` into `out` (scope backend).
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn par_col_sums_into(plan: &Matrix, part: &Partition, out: &mut [f32], acc: &mut AccArena) {
    let n = plan.cols();
    thread::scope(|s| {
        let handles: Vec<_> = acc
            .rows_mut()
            .take(part.blocks())
            .enumerate()
            .map(|(b, local)| {
                let r = part.range(b);
                let block = &plan.as_slice()[r.start * n..r.end * n];
                s.spawn(move || {
                    local.fill(0.0);
                    for row in block.chunks_exact(n) {
                        for (sl, &v) in local.iter_mut().zip(row) {
                            *sl += v;
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    reduce_acc(out, acc, part.blocks());
}

/// Parallel column sums of `plan` into `out` (pool backend).
fn par_col_sums_pool(
    plan: &Matrix,
    part: &Partition,
    out: &mut [f32],
    acc: &mut AccArena,
    pool: &ThreadPool,
) {
    let n = plan.cols();
    let arena = acc.shared();
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        // SAFETY: part `b` is the only user of accumulator `b`.
        let local = unsafe { arena.row_mut(b) };
        local.fill(0.0);
        for row in plan.as_slice()[r.start * n..r.end * n].chunks_exact(n) {
            for (sl, &v) in local.iter_mut().zip(row) {
                *sl += v;
            }
        }
    });
    reduce_acc_pool(out, acc, part.blocks(), pool);
}

// ---------------------------------------------------------------------------
// MAP-UOT
// ---------------------------------------------------------------------------

/// One parallel MAP-UOT iteration out of caller-provided scratch:
/// `fcol` (length N) and the `NextSum_col` arena `acc` (scope backend).
/// Runs the legacy policy (unrolled kernel, untiled, cached stores) so its
/// numerics are bit-stable; the session path uses
/// [`mapuot_iterate_policy`].
pub fn mapuot_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    acc: &mut AccArena,
) {
    let legacy = KernelPolicy::legacy();
    mapuot_scope(plan, colsum, rpd, cpd, fi, threads, fcol, None, &mut [], acc, &legacy);
}

/// [`mapuot_iterate_into`] with in-sweep delta tracking; returns the
/// iteration's max element change across all row blocks.
pub fn mapuot_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut AccArena,
) -> f32 {
    mapuot_scope(
        plan,
        colsum,
        rpd,
        cpd,
        fi,
        threads,
        fcol,
        Some(inv_fcol),
        &mut [],
        acc,
        &KernelPolicy::legacy(),
    )
}

/// [`mapuot_iterate_into`] under an explicit [`KernelPolicy`]: kernel
/// dispatch + NT stores + column tiling, composed with the row partition
/// (each thread tiles its own row block). `rowsum` is `Sum_row` scratch of
/// at least `plan.rows()` floats when the policy tiles (the workspace's
/// `rowsum` buffer — blocks use disjoint segments of it).
#[allow(clippy::too_many_arguments)]
pub fn mapuot_iterate_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    policy: &KernelPolicy,
) {
    mapuot_scope(plan, colsum, rpd, cpd, fi, threads, fcol, None, rowsum, acc, policy);
}

/// [`mapuot_iterate_policy`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn mapuot_iterate_tracked_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    policy: &KernelPolicy,
) -> f32 {
    mapuot_scope(plan, colsum, rpd, cpd, fi, threads, fcol, Some(inv_fcol), rowsum, acc, policy)
}

/// Shared body of the scope-backend MAP-UOT iteration.
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn mapuot_scope(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
    policy: &KernelPolicy,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, effective_threads(threads, m), acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    // The NT-store decision is made from the whole plan, once per
    // iteration: every block streams the same matrix.
    let stream = policy.stream_for(m * n);
    let tiled = policy.tile_for(n).is_some();
    let policy = *policy;

    let fcol_ref: &[f32] = fcol;
    let mut delta = 0f32;
    thread::scope(|s| {
        let mut rest: &mut [f32] = plan.as_mut_slice();
        let mut rs_rest: &mut [f32] = rowsum;
        let handles: Vec<_> = acc
            .rows_mut()
            .take(part.blocks())
            .enumerate()
            .map(|(b, local)| {
                let r = part.range(b);
                let (block, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * n);
                rest = tail;
                // Sum_row scratch only exists (and is only needed) when
                // the policy tiles; untiled blocks get an empty segment.
                let (rs_block, rs_tail) =
                    std::mem::take(&mut rs_rest).split_at_mut(if tiled { r.len() } else { 0 });
                rs_rest = rs_tail;
                let rpd_block = &rpd[r.start..r.end];
                s.spawn(move || {
                    local.fill(0.0);
                    fused_rows_opt(
                        block, n, rpd_block, fcol_ref, inv, fi, local, rs_block, &policy, stream,
                    )
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, part.blocks());
    delta
}

/// One MAP-UOT iteration on the persistent pool: zero spawns, zero
/// allocations, one epoch for the fused sweep + one for the reduction.
/// Legacy policy (see [`mapuot_iterate_into`]); the session path uses
/// [`mapuot_iterate_pool_policy`].
pub fn mapuot_iterate_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    acc: &mut AccArena,
) {
    let legacy = KernelPolicy::legacy();
    mapuot_pool(plan, colsum, rpd, cpd, fi, pool, fcol, None, &mut [], acc, None, &legacy);
}

/// [`mapuot_iterate_pool`] with in-sweep delta tracking.
pub fn mapuot_iterate_pool_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
) -> f32 {
    mapuot_pool(
        plan,
        colsum,
        rpd,
        cpd,
        fi,
        pool,
        fcol,
        Some(inv_fcol),
        &mut [],
        acc,
        Some(deltas),
        &KernelPolicy::legacy(),
    )
}

/// [`mapuot_iterate_pool`] under an explicit [`KernelPolicy`] — tiling
/// composes with the row partition exactly as in the scope backend, so
/// pool and scope stay bit-identical for equal policies.
#[allow(clippy::too_many_arguments)]
pub fn mapuot_iterate_pool_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    policy: &KernelPolicy,
) {
    mapuot_pool(plan, colsum, rpd, cpd, fi, pool, fcol, None, rowsum, acc, None, policy);
}

/// [`mapuot_iterate_pool_policy`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn mapuot_iterate_pool_tracked_policy(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
    policy: &KernelPolicy,
) -> f32 {
    mapuot_pool(
        plan,
        colsum,
        rpd,
        cpd,
        fi,
        pool,
        fcol,
        Some(inv_fcol),
        rowsum,
        acc,
        Some(deltas),
        policy,
    )
}

/// Shared body of the pool-backend MAP-UOT iteration.
#[allow(clippy::too_many_arguments)]
fn mapuot_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: Option<&mut PaddedSlots>,
    policy: &KernelPolicy,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, pool.threads(), acc.rows());
    // Preconditions the disjoint-split SAFETY arguments below lean on.
    debug_assert_eq!(rpd.len(), m, "rpd length != plan rows");
    debug_assert!(rowsum.len() >= m, "rowsum shorter than plan rows");
    debug_assert_eq!(acc.cols(), n, "accumulator width != plan cols");
    debug_assert!(part.blocks() <= acc.rows(), "partition exceeds arena rows");
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    let stream = policy.stream_for(m * n);
    let tiled = policy.tile_for(n).is_some();

    let fcol_ref: &[f32] = fcol;
    let plan_ref = SliceRef::new(plan.as_mut_slice());
    let rows_ref = SliceRef::new(rowsum);
    let arena = acc.shared();
    let mut deltas = deltas;
    let slots = deltas.as_mut().map(|d| d.shared());
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        // SAFETY: the partition's row blocks are disjoint, so the plan
        // ranges `r.start*n..r.end*n` of distinct parts never overlap.
        let block = unsafe { plan_ref.range_mut(r.start * n, r.end * n) };
        // SAFETY: accumulator row `b` belongs to part `b` alone.
        let local = unsafe { arena.row_mut(b) };
        let rs_block = if tiled {
            // SAFETY: rowsum segments mirror the disjoint row blocks.
            unsafe { rows_ref.range_mut(r.start, r.end) }
        } else {
            // SAFETY: the empty range aliases nothing.
            unsafe { rows_ref.range_mut(0, 0) }
        };
        local.fill(0.0);
        let rpd_block = &rpd[r.start..r.end];
        let bd = fused_rows_opt(
            block, n, rpd_block, fcol_ref, inv, fi, local, rs_block, policy, stream,
        );
        if let Some(slots) = slots {
            // SAFETY: slot `b` belongs to part `b` alone.
            unsafe { slots.set(b, bd) };
        }
    });
    reduce_acc_pool(colsum, acc, part.blocks(), pool);
    deltas.map(|d| d.fold_max(part.blocks())).unwrap_or(0.0)
}

/// One parallel MAP-UOT iteration with `threads` workers; allocates its own
/// scratch per call — prefer [`mapuot_iterate_into`] on hot paths.
pub fn mapuot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut acc = AccArena::padded(t, n);
    mapuot_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut acc);
}

// ---------------------------------------------------------------------------
// Sparse MAP-UOT (CSR)
// ---------------------------------------------------------------------------
//
// The CSR fused sweep parallelizes exactly like the dense one — contiguous
// row blocks, private `NextSum_col` partials in the cache-line-padded
// `AccArena`, block-ascending reduction — except that the blocks come from
// an nnz-balanced `NnzPartition` (CSR row lengths are skewed, so an
// even-rows split would leave stragglers). All three drivers (scope
// engine, pool engine, and the partitioned serial reference) run the same
// per-block body (`sparse::fused_csr_rows`) over the same partition and
// reduce in the same order, so for identical inputs they produce
// **bit-identical** values, column sums and tracked deltas — property-
// tested in `rust/tests/prop_sparse.rs`.

/// One sparse MAP-UOT iteration on the `thread::scope` engine out of
/// caller-provided scratch: `fcol` (length N), the `NextSum_col` arena
/// `acc`, and an [`NnzPartition`] that tiles `a`'s rows with at most
/// `acc.rows()` blocks.
pub fn sparse_mapuot_iterate_into(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    acc: &mut AccArena,
    part: &NnzPartition,
) {
    sparse_scope(a, colsum, rpd, cpd, fi, fcol, None, acc, part);
}

/// [`sparse_mapuot_iterate_into`] with in-sweep delta tracking; returns
/// the iteration's max element change across all row blocks.
#[allow(clippy::too_many_arguments)]
pub fn sparse_mapuot_iterate_tracked(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut AccArena,
    part: &NnzPartition,
) -> f32 {
    sparse_scope(a, colsum, rpd, cpd, fi, fcol, Some(inv_fcol), acc, part)
}

/// Shared body of the scope-engine sparse iteration.
#[allow(clippy::too_many_arguments)]
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn sparse_scope(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    acc: &mut AccArena,
    part: &NnzPartition,
) -> f32 {
    debug_assert_eq!(part.rows(), a.m, "partition must tile the matrix rows");
    debug_assert!(part.blocks() <= acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    let fcol_ref: &[f32] = fcol;
    let row_ptr: &[usize] = &a.row_ptr;
    let col_idx: &[u32] = &a.col_idx;
    let mut delta = 0f32;
    thread::scope(|s| {
        let mut rest: &mut [f32] = a.values.as_mut_slice();
        let handles: Vec<_> = acc
            .rows_mut()
            .take(part.blocks())
            .enumerate()
            .map(|(b, local)| {
                let r = part.range(b);
                let (rs, re) = (r.start, r.end);
                let base = row_ptr[rs];
                let (block, tail) =
                    std::mem::take(&mut rest).split_at_mut(row_ptr[re] - base);
                rest = tail;
                s.spawn(move || {
                    local.fill(0.0);
                    fused_csr_rows(
                        block, base, row_ptr, col_idx, rs..re, rpd, fcol_ref, inv, fi, local,
                    )
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, part.blocks());
    delta
}

/// One sparse MAP-UOT iteration on the persistent pool: zero spawns, zero
/// allocations, one epoch for the fused sweep + one for the reduction.
/// `part.blocks()` must not exceed `pool.threads()` (a workspace built for
/// the pool guarantees this).
#[allow(clippy::too_many_arguments)]
pub fn sparse_mapuot_iterate_pool(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    acc: &mut AccArena,
    part: &NnzPartition,
) {
    sparse_pool(a, colsum, rpd, cpd, fi, pool, fcol, None, acc, None, part);
}

/// [`sparse_mapuot_iterate_pool`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn sparse_mapuot_iterate_pool_tracked(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
    part: &NnzPartition,
) -> f32 {
    sparse_pool(a, colsum, rpd, cpd, fi, pool, fcol, Some(inv_fcol), acc, Some(deltas), part)
}

/// Shared body of the pool-engine sparse iteration.
#[allow(clippy::too_many_arguments)]
fn sparse_pool(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    acc: &mut AccArena,
    deltas: Option<&mut PaddedSlots>,
    part: &NnzPartition,
) -> f32 {
    debug_assert_eq!(part.rows(), a.m, "partition must tile the matrix rows");
    debug_assert!(part.blocks() <= acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    let fcol_ref: &[f32] = fcol;
    let row_ptr: &[usize] = &a.row_ptr;
    let col_idx: &[u32] = &a.col_idx;
    let vals = SliceRef::new(a.values.as_mut_slice());
    let arena = acc.shared();
    let mut deltas = deltas;
    let slots = deltas.as_mut().map(|d| d.shared());
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        let (base, end) = (row_ptr[r.start], row_ptr[r.end]);
        // SAFETY: the nnz ranges of distinct blocks are disjoint (row_ptr
        // is monotone and the partition tiles the rows).
        let block = unsafe { vals.range_mut(base, end) };
        // SAFETY: accumulator row `b` belongs to part `b` alone.
        let local = unsafe { arena.row_mut(b) };
        local.fill(0.0);
        let bd = fused_csr_rows(block, base, row_ptr, col_idx, r, rpd, fcol_ref, inv, fi, local);
        if let Some(slots) = slots {
            // SAFETY: slot `b` belongs to part `b` alone.
            unsafe { slots.set(b, bd) };
        }
    });
    reduce_acc_pool(colsum, acc, part.blocks(), pool);
    deltas.map(|d| d.fold_max(part.blocks())).unwrap_or(0.0)
}

/// Partitioned **serial reference** of the sparse iteration: the exact
/// per-block fused passes and block-ascending colsum reduction the two
/// threaded engines run, executed sequentially on the calling thread.
/// This is the bit-exactness oracle `prop_sparse.rs` holds both engines
/// to, for any fixed partition.
#[allow(clippy::too_many_arguments)]
pub fn sparse_mapuot_iterate_partitioned_tracked(
    a: &mut CsrMatrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    acc: &mut AccArena,
    part: &NnzPartition,
) -> f32 {
    debug_assert_eq!(part.rows(), a.m, "partition must tile the matrix rows");
    debug_assert!(part.blocks() <= acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    recip_into(inv_fcol, fcol);
    let fcol_ref: &[f32] = fcol;
    let inv_ref: &[f32] = inv_fcol;
    let mut delta = 0f32;
    for b in 0..part.blocks() {
        let r = part.range(b);
        let (base, end) = (a.row_ptr[r.start], a.row_ptr[r.end]);
        let local = acc.row_mut(b);
        local.fill(0.0);
        let (row_ptr, col_idx) = (&a.row_ptr, &a.col_idx);
        let block = &mut a.values[base..end];
        delta = delta.max(fused_csr_rows(
            block,
            base,
            row_ptr,
            col_idx,
            r,
            rpd,
            fcol_ref,
            Some(inv_ref),
            fi,
            local,
        ));
    }
    reduce_acc(colsum, acc, part.blocks());
    delta
}

// ---------------------------------------------------------------------------
// Matfree MAP-UOT (scaling form over on-the-fly kernels)
// ---------------------------------------------------------------------------
//
// The materialization-free sweep parallelizes exactly like the dense one —
// contiguous row blocks (every matfree row costs the same n kernel
// evaluations, so the dense even `Partition` is the right split), private
// `NextSum_col` partials in the padded `AccArena`, block-ascending
// reduction — plus one padded row-generation panel per block (a second
// arena). The carried state the engines advance is the scaling vectors
// `u`/`v` and the marginal sums, never a plan. All three drivers (the
// partitioned serial reference, scope, pool) run the same per-block body
// (`matfree::matfree_rows_opt`) over the same partition and reduce in the
// same order, so for identical inputs they are **bit-identical**
// (`rust/tests/prop_matfree.rs`).

/// One matfree MAP-UOT iteration on the `thread::scope` engine out of
/// caller-provided scratch: `fcol` (length N), the generation-panel arena
/// `panels`, the `NextSum_col` arena `acc`, and a [`Partition`] tiling the
/// rows with at most `min(acc.rows(), panels.rows())` blocks. Advances
/// `u`/`v` in place and refreshes the carried `colsum`/`rowsum`.
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_into(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    matfree_scope(p, u, v, colsum, rowsum, fcol, None, panels, acc, part, policy);
}

/// [`matfree_iterate_into`] with in-sweep delta tracking; returns the
/// iteration's max plan element change across all row blocks.
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_tracked(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    matfree_scope(p, u, v, colsum, rowsum, fcol, Some(inv_fcol), panels, acc, part, policy)
}

/// Shared body of the scope-engine matfree iteration.
#[allow(clippy::too_many_arguments)]
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn matfree_scope(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert_eq!(v.len(), p.cols());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    factors_into(fcol, &p.cpd, colsum, p.fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    // Fold the column factors into v on the dispatching thread — identical
    // on every engine, so the carried v bits never depend on the engine.
    for (vj, &f) in v.iter_mut().zip(fcol.iter()) {
        *vj *= f;
    }
    let v_ref: &[f32] = v;
    let policy = *policy;
    let mut delta = 0f32;
    thread::scope(|s| {
        let mut u_rest: &mut [f32] = u;
        let mut rs_rest: &mut [f32] = rowsum;
        let handles: Vec<_> = panels
            .rows_mut()
            .zip(acc.rows_mut())
            .take(part.blocks())
            .enumerate()
            .map(|(b, (buf, local))| {
                let r = part.range(b);
                let (u_block, u_tail) = std::mem::take(&mut u_rest).split_at_mut(r.len());
                u_rest = u_tail;
                let (rs_block, rs_tail) = std::mem::take(&mut rs_rest).split_at_mut(r.len());
                rs_rest = rs_tail;
                s.spawn(move || {
                    local.fill(0.0);
                    matfree_rows_opt(p, r, u_block, rs_block, v_ref, inv, buf, local, &policy)
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, part.blocks());
    delta
}

/// One matfree iteration on the persistent pool: zero spawns, zero
/// allocations, one epoch for the generation sweep + one for the
/// reduction. `part.blocks()` must not exceed `pool.threads()` (a
/// workspace built for the pool guarantees this).
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_pool(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    pool: &ThreadPool,
    fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    matfree_pool(p, u, v, colsum, rowsum, pool, fcol, None, panels, acc, None, part, policy);
}

/// [`matfree_iterate_pool`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_pool_tracked(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    matfree_pool(
        p,
        u,
        v,
        colsum,
        rowsum,
        pool,
        fcol,
        Some(inv_fcol),
        panels,
        acc,
        Some(deltas),
        part,
        policy,
    )
}

/// Shared body of the pool-engine matfree iteration.
#[allow(clippy::too_many_arguments)]
fn matfree_pool(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    panels: &mut AccArena,
    acc: &mut AccArena,
    deltas: Option<&mut PaddedSlots>,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    factors_into(fcol, &p.cpd, colsum, p.fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    for (vj, &f) in v.iter_mut().zip(fcol.iter()) {
        *vj *= f;
    }
    let v_ref: &[f32] = v;
    let u_ref = SliceRef::new(u);
    let rs_ref = SliceRef::new(rowsum);
    let panel_arena = panels.shared();
    let arena = acc.shared();
    let mut deltas = deltas;
    let slots = deltas.as_mut().map(|d| d.shared());
    let policy = *policy;
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        // SAFETY: the partition's row blocks are disjoint, so the `u`
        // segments of distinct parts never overlap.
        let u_block = unsafe { u_ref.range_mut(r.start, r.end) };
        // SAFETY: rowsum segments mirror the same disjoint row blocks.
        let rs_block = unsafe { rs_ref.range_mut(r.start, r.end) };
        // SAFETY: panel row `b` belongs to part `b` alone.
        let buf = unsafe { panel_arena.row_mut(b) };
        // SAFETY: accumulator row `b` belongs to part `b` alone.
        let local = unsafe { arena.row_mut(b) };
        local.fill(0.0);
        let bd = matfree_rows_opt(p, r, u_block, rs_block, v_ref, inv, buf, local, &policy);
        if let Some(slots) = slots {
            // SAFETY: slot `b` belongs to part `b` alone.
            unsafe { slots.set(b, bd) };
        }
    });
    reduce_acc_pool(colsum, acc, part.blocks(), pool);
    deltas.map(|d| d.fold_max(part.blocks())).unwrap_or(0.0)
}

/// Partitioned **serial reference** of the matfree iteration: the exact
/// per-block generation passes and block-ascending colsum reduction the
/// two threaded engines run, executed sequentially on the calling thread
/// — the bit-exactness oracle `prop_matfree.rs` holds both engines to,
/// for any fixed partition. Also the session's `threads == 1` path.
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_partitioned(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    matfree_partitioned(p, u, v, colsum, rowsum, fcol, None, panels, acc, part, policy);
}

/// [`matfree_iterate_partitioned`] with in-sweep delta tracking.
#[allow(clippy::too_many_arguments)]
pub fn matfree_iterate_partitioned_tracked(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    matfree_partitioned(p, u, v, colsum, rowsum, fcol, Some(inv_fcol), panels, acc, part, policy)
}

/// Shared body of the partitioned serial matfree iteration.
#[allow(clippy::too_many_arguments)]
fn matfree_partitioned(
    p: &GeomProblem,
    u: &mut [f32],
    v: &mut [f32],
    colsum: &mut [f32],
    rowsum: &mut [f32],
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) -> f32 {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    factors_into(fcol, &p.cpd, colsum, p.fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };
    for (vj, &f) in v.iter_mut().zip(fcol.iter()) {
        *vj *= f;
    }
    let v_ref: &[f32] = v;
    let mut delta = 0f32;
    for b in 0..part.blocks() {
        let r = part.range(b);
        let local = acc.row_mut(b);
        local.fill(0.0);
        let buf = panels.row_mut(b);
        delta = delta.max(matfree_rows_opt(
            p,
            r.clone(),
            &mut u[r.clone()],
            &mut rowsum[r],
            v_ref,
            inv,
            buf,
            local,
            policy,
        ));
    }
    reduce_acc(colsum, acc, part.blocks());
    delta
}

// Matfree column-sum seeding (the per-solve `Σ_i u_i · A_ij · v_j` pass
// that derives the carried `colsum` before iterating — cold, warm-started,
// or at an ε-schedule rung handoff). Same engine contract as the
// iteration: all three variants run `matfree::matfree_seed_rows` over the
// same partition and reduce block-ascending, so for identical inputs they
// are **bit-identical** (`rust/tests/prop_warmstart.rs`).

/// Partitioned **serial reference** of the matfree seeding pass — the
/// bit-exactness oracle for the two threaded engines, and the session's
/// `threads == 1` path.
pub fn matfree_seed_partitioned(
    p: &GeomProblem,
    u: &[f32],
    v: &[f32],
    colsum: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    for b in 0..part.blocks() {
        let r = part.range(b);
        let local = acc.row_mut(b);
        let buf = panels.row_mut(b);
        matfree_seed_rows(p, r, u, v, buf, local, policy);
    }
    reduce_acc(colsum, acc, part.blocks());
}

/// The matfree seeding pass on the `thread::scope` engine.
pub fn matfree_seed_scope(
    p: &GeomProblem,
    u: &[f32],
    v: &[f32],
    colsum: &mut [f32],
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    let policy = *policy;
    thread::scope(|s| {
        let handles: Vec<_> = panels
            .rows_mut()
            .zip(acc.rows_mut())
            .take(part.blocks())
            .enumerate()
            .map(|(b, (buf, local))| {
                let r = part.range(b);
                s.spawn(move || matfree_seed_rows(p, r, u, v, buf, local, &policy))
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    reduce_acc(colsum, acc, part.blocks());
}

/// The matfree seeding pass on the persistent pool: zero spawns, zero
/// allocations, one epoch for the generation sweep + one for the
/// reduction. `part.blocks()` must not exceed `pool.threads()` (a
/// workspace built for the pool guarantees this).
#[allow(clippy::too_many_arguments)]
pub fn matfree_seed_pool(
    p: &GeomProblem,
    u: &[f32],
    v: &[f32],
    colsum: &mut [f32],
    pool: &ThreadPool,
    panels: &mut AccArena,
    acc: &mut AccArena,
    part: &Partition,
    policy: &KernelPolicy,
) {
    debug_assert_eq!(u.len(), p.rows());
    debug_assert!(part.blocks() <= acc.rows().min(panels.rows()));
    let panel_arena = panels.shared();
    let arena = acc.shared();
    let policy = *policy;
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        // SAFETY: panel row `b` belongs to part `b` alone.
        let buf = unsafe { panel_arena.row_mut(b) };
        // SAFETY: accumulator row `b` belongs to part `b` alone.
        let local = unsafe { arena.row_mut(b) };
        matfree_seed_rows(p, r, u, v, buf, local, &policy);
    });
    reduce_acc_pool(colsum, acc, part.blocks(), pool);
}

// ---------------------------------------------------------------------------
// COFFEE
// ---------------------------------------------------------------------------

/// One parallel COFFEE iteration (two phase-sweeps with a barrier between)
/// out of caller-provided scratch (scope backend).
pub fn coffee_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) {
    coffee_phases(plan, colsum, rpd, cpd, fi, threads, fcol, None, rowsum, acc);
}

/// [`coffee_iterate_into`] with in-sweep delta tracking.
pub fn coffee_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) -> f32 {
    coffee_phases(plan, colsum, rpd, cpd, fi, threads, fcol, Some(inv_fcol), rowsum, acc)
}

/// Shared body of the scope-backend COFFEE iteration; tracks deltas in
/// phase B when `inv_fcol` is provided (same pattern as [`pot_sweeps`]).
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn coffee_phases(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, effective_threads(threads, m), acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };

    // Phase A: column rescale + row sums.
    let fcol_ref: &[f32] = fcol;
    thread::scope(|s| {
        let mut rest: &mut [f32] = plan.as_mut_slice();
        let mut rs_rest: &mut [f32] = &mut *rowsum;
        for b in 0..part.blocks() {
            let r = part.range(b);
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * n);
            rest = tail;
            let (rs_block, rs_tail) = std::mem::take(&mut rs_rest).split_at_mut(r.len());
            rs_rest = rs_tail;
            s.spawn(move || {
                for (row, rs) in block.chunks_exact_mut(n).zip(rs_block.iter_mut()) {
                    *rs = scale_by_vec_and_sum(row, fcol_ref);
                }
            });
        }
    });

    // Phase B: row rescale + next column sums (tracked when the reciprocal
    // factors are given).
    let rowsum_ref: &[f32] = rowsum;
    let mut delta = 0f32;
    thread::scope(|s| {
        let mut rest: &mut [f32] = plan.as_mut_slice();
        let handles: Vec<_> = acc
            .rows_mut()
            .take(part.blocks())
            .enumerate()
            .map(|(b, local)| {
                let r = part.range(b);
                let (block, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * n);
                rest = tail;
                s.spawn(move || {
                    local.fill(0.0);
                    coffee_phase_b_block(block, n, r.start, rpd, rowsum_ref, fi, inv, local)
                })
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });
    reduce_acc(colsum, acc, part.blocks());
    delta
}

/// COFFEE phase B over one row block: row rescale + `NextSum_col`
/// accumulation, tracked when `inv` is provided. Shared by both backends.
fn coffee_phase_b_block(
    block: &mut [f32],
    n: usize,
    row0: usize,
    rpd: &[f32],
    rowsum: &[f32],
    fi: f32,
    inv: Option<&[f32]>,
    local: &mut [f32],
) -> f32 {
    let mut block_delta = 0f32;
    for (i, row) in block.chunks_exact_mut(n).enumerate() {
        let gi = row0 + i;
        let fr = factor(rpd[gi], rowsum[gi], fi);
        match inv {
            Some(iv) => {
                block_delta =
                    block_delta.max(scale_by_scalar_and_accumulate_tracked(row, fr, iv, local));
            }
            None => {
                for (v, sl) in row.iter_mut().zip(local.iter_mut()) {
                    *v *= fr;
                    *sl += *v;
                }
            }
        }
    }
    block_delta
}

/// One COFFEE iteration on the persistent pool (two epochs + reduction;
/// the phase barrier is an epoch wait, not a scope teardown).
pub fn coffee_iterate_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) {
    coffee_pool(plan, colsum, rpd, cpd, fi, pool, fcol, None, rowsum, acc, None);
}

/// [`coffee_iterate_pool`] with in-sweep delta tracking.
pub fn coffee_iterate_pool_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
) -> f32 {
    coffee_pool(plan, colsum, rpd, cpd, fi, pool, fcol, Some(inv_fcol), rowsum, acc, Some(deltas))
}

/// Shared body of the pool-backend COFFEE iteration.
fn coffee_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: Option<&mut PaddedSlots>,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, pool.threads(), acc.rows());
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };

    // Phase A: column rescale + row sums (epoch 1).
    let fcol_ref: &[f32] = fcol;
    {
        let plan_ref = SliceRef::new(plan.as_mut_slice());
        let rows_ref = SliceRef::new(rowsum);
        pool.run(part.blocks(), |b| {
            let r = part.range(b);
            // SAFETY: the partition's row blocks are disjoint, so the plan
            // ranges `r.start*n..r.end*n` of distinct parts never overlap.
            let block = unsafe { plan_ref.range_mut(r.start * n, r.end * n) };
            // SAFETY: rowsum segments mirror the same disjoint row blocks.
            let rs_block = unsafe { rows_ref.range_mut(r.start, r.end) };
            for (row, rs) in block.chunks_exact_mut(n).zip(rs_block.iter_mut()) {
                *rs = scale_by_vec_and_sum(row, fcol_ref);
            }
        });
    }

    // Phase B: row rescale + next column sums (epoch 2).
    let rowsum_ref: &[f32] = rowsum;
    let plan_ref = SliceRef::new(plan.as_mut_slice());
    let arena = acc.shared();
    let mut deltas = deltas;
    let slots = deltas.as_mut().map(|d| d.shared());
    pool.run(part.blocks(), |b| {
        let r = part.range(b);
        // SAFETY: the partition's row blocks are disjoint, so the plan
        // ranges `r.start*n..r.end*n` of distinct parts never overlap.
        let block = unsafe { plan_ref.range_mut(r.start * n, r.end * n) };
        // SAFETY: accumulator row `b` belongs to part `b` alone.
        let local = unsafe { arena.row_mut(b) };
        local.fill(0.0);
        let bd = coffee_phase_b_block(block, n, r.start, rpd, rowsum_ref, fi, inv, local);
        if let Some(slots) = slots {
            // SAFETY: slot `b` belongs to part `b` alone.
            unsafe { slots.set(b, bd) };
        }
    });
    reduce_acc_pool(colsum, acc, part.blocks(), pool);
    deltas.map(|d| d.fold_max(part.blocks())).unwrap_or(0.0)
}

/// One parallel COFFEE iteration; allocates its own scratch per call —
/// prefer [`coffee_iterate_into`] on hot paths.
pub fn coffee_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut rowsum = vec![0f32; m];
    let mut acc = AccArena::padded(t, n);
    coffee_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut rowsum, &mut acc);
}

// ---------------------------------------------------------------------------
// POT
// ---------------------------------------------------------------------------

/// One parallel POT iteration (four sweeps, each row-partitioned, with
/// barriers between — the NumPy execution model under a parallel BLAS-style
/// backend) out of caller-provided scratch (scope backend).
pub fn pot_iterate_into(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) {
    pot_sweeps(plan, colsum, rpd, cpd, fi, threads, fcol, None, rowsum, acc);
}

/// [`pot_iterate_into`] with in-sweep delta tracking.
pub fn pot_iterate_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) -> f32 {
    pot_sweeps(plan, colsum, rpd, cpd, fi, threads, fcol, Some(inv_fcol), rowsum, acc)
}

/// Shared body of the scope-backend POT iteration; tracks deltas in sweep 4
/// when `inv_fcol` is provided.
// uotlint: allow(alloc) — scope engine spawns OS threads per call by
// design (join-handle Vec included); the persistent pool engine is the
// allocation-free path (tests/alloc_free.rs exempts scope likewise).
fn pot_sweeps(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, effective_threads(threads, m), acc.rows());

    // Sweep 1: column sums.
    par_col_sums_into(plan, &part, colsum, acc);
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };

    // Sweep 2: column rescale.
    let fcol_ref: &[f32] = fcol;
    thread::scope(|s| {
        let mut rest: &mut [f32] = plan.as_mut_slice();
        for b in 0..part.blocks() {
            let r = part.range(b);
            let (block, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * n);
            rest = tail;
            s.spawn(move || {
                for row in block.chunks_exact_mut(n) {
                    for (v, &f) in row.iter_mut().zip(fcol_ref) {
                        *v *= f;
                    }
                }
            });
        }
    });

    // Sweep 3: row sums.
    thread::scope(|s| {
        let mut rs_rest: &mut [f32] = &mut *rowsum;
        for b in 0..part.blocks() {
            let r = part.range(b);
            let block = &plan.as_slice()[r.start * n..r.end * n];
            let (rs_block, rs_tail) = std::mem::take(&mut rs_rest).split_at_mut(r.len());
            rs_rest = rs_tail;
            s.spawn(move || {
                for (row, rs) in block.chunks_exact(n).zip(rs_block.iter_mut()) {
                    *rs = row.iter().sum::<f32>();
                }
            });
        }
    });

    // Sweep 4: row rescale (tracked when the reciprocal factors are given).
    let rowsum_ref: &[f32] = rowsum;
    let mut delta = 0f32;
    thread::scope(|s| {
        let mut rest: &mut [f32] = plan.as_mut_slice();
        let handles: Vec<_> = (0..part.blocks())
            .map(|b| {
                let r = part.range(b);
                let (block, tail) = std::mem::take(&mut rest).split_at_mut(r.len() * n);
                rest = tail;
                s.spawn(move || pot_sweep4_block(block, n, r.start, rpd, rowsum_ref, fi, inv))
            })
            .collect();
        for h in handles {
            delta = delta.max(h.join().expect("worker panicked"));
        }
    });

    // Refresh carried colsum (POT recomputes it next iteration anyway).
    par_col_sums_into(plan, &part, colsum, acc);
    delta
}

/// POT sweep 4 over one row block: row rescale, tracked when `inv` is
/// provided. Shared by both backends.
fn pot_sweep4_block(
    block: &mut [f32],
    n: usize,
    row0: usize,
    rpd: &[f32],
    rowsum: &[f32],
    fi: f32,
    inv: Option<&[f32]>,
) -> f32 {
    let mut block_delta = 0f32;
    for (i, row) in block.chunks_exact_mut(n).enumerate() {
        let gi = row0 + i;
        let fr = factor(rpd[gi], rowsum[gi], fi);
        match inv {
            Some(iv) => {
                for (v, &ivj) in row.iter_mut().zip(iv) {
                    let old = *v * ivj;
                    *v *= fr;
                    block_delta = block_delta.max((*v - old).abs());
                }
            }
            None => {
                for v in row.iter_mut() {
                    *v *= fr;
                }
            }
        }
    }
    block_delta
}

/// One POT iteration on the persistent pool: the four sweep barriers are
/// epoch waits (five epochs per iteration with the colsum refresh), not
/// four scope teardowns.
pub fn pot_iterate_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
) {
    pot_pool(plan, colsum, rpd, cpd, fi, pool, fcol, None, rowsum, acc, None);
}

/// [`pot_iterate_pool`] with in-sweep delta tracking.
pub fn pot_iterate_pool_tracked(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: &mut [f32],
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: &mut PaddedSlots,
) -> f32 {
    pot_pool(plan, colsum, rpd, cpd, fi, pool, fcol, Some(inv_fcol), rowsum, acc, Some(deltas))
}

/// Shared body of the pool-backend POT iteration.
fn pot_pool(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    pool: &ThreadPool,
    fcol: &mut [f32],
    inv_fcol: Option<&mut [f32]>,
    rowsum: &mut [f32],
    acc: &mut AccArena,
    deltas: Option<&mut PaddedSlots>,
) -> f32 {
    let (m, n) = (plan.rows(), plan.cols());
    let part = Partition::new(m, pool.threads(), acc.rows());

    // Sweep 1: column sums.
    par_col_sums_pool(plan, &part, colsum, acc, pool);
    factors_into(fcol, cpd, colsum, fi);
    let inv: Option<&[f32]> = match inv_fcol {
        Some(iv) => {
            recip_into(iv, fcol);
            Some(iv)
        }
        None => None,
    };

    // Sweep 2: column rescale.
    let fcol_ref: &[f32] = fcol;
    {
        let plan_ref = SliceRef::new(plan.as_mut_slice());
        pool.run(part.blocks(), |b| {
            let r = part.range(b);
            // SAFETY: row blocks are disjoint.
            let block = unsafe { plan_ref.range_mut(r.start * n, r.end * n) };
            for row in block.chunks_exact_mut(n) {
                for (v, &f) in row.iter_mut().zip(fcol_ref) {
                    *v *= f;
                }
            }
        });
    }

    // Sweep 3: row sums (plan is read-only here).
    {
        let rows_ref = SliceRef::new(rowsum);
        let plan_view: &Matrix = plan;
        pool.run(part.blocks(), |b| {
            let r = part.range(b);
            // SAFETY: rowsum segments are disjoint.
            let rs_block = unsafe { rows_ref.range_mut(r.start, r.end) };
            let data = &plan_view.as_slice()[r.start * n..r.end * n];
            for (row, rs) in data.chunks_exact(n).zip(rs_block.iter_mut()) {
                *rs = row.iter().sum::<f32>();
            }
        });
    }

    // Sweep 4: row rescale (tracked when the reciprocal factors are given).
    let rowsum_ref: &[f32] = rowsum;
    let delta;
    {
        let plan_ref = SliceRef::new(plan.as_mut_slice());
        let mut deltas = deltas;
        let slots = deltas.as_mut().map(|d| d.shared());
        pool.run(part.blocks(), |b| {
            let r = part.range(b);
            // SAFETY: disjoint row blocks; slot `b` is part-owned.
            let block = unsafe { plan_ref.range_mut(r.start * n, r.end * n) };
            let bd = pot_sweep4_block(block, n, r.start, rpd, rowsum_ref, fi, inv);
            if let Some(slots) = slots {
                // SAFETY: slot `b` belongs to part `b` alone.
                unsafe { slots.set(b, bd) };
            }
        });
        delta = deltas.map(|d| d.fold_max(part.blocks())).unwrap_or(0.0);
    }

    // Refresh carried colsum (POT recomputes it next iteration anyway).
    par_col_sums_pool(plan, &part, colsum, acc, pool);
    delta
}

/// One parallel POT iteration; allocates its own scratch per call —
/// prefer [`pot_iterate_into`] on hot paths.
pub fn pot_iterate(
    plan: &mut Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = effective_threads(threads, m);
    let mut fcol = vec![0f32; n];
    let mut rowsum = vec![0f32; m];
    let mut acc = AccArena::padded(t, n);
    pot_iterate_into(plan, colsum, rpd, cpd, fi, threads, &mut fcol, &mut rowsum, &mut acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{mapuot, problem::Problem};

    fn check_parallel_matches_serial(
        par: impl Fn(&mut Matrix, &mut [f32], &[f32], &[f32], f32, usize),
        threads: usize,
        seed: u64,
    ) {
        let p = Problem::random(23, 17, 0.7, seed);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        for _ in 0..5 {
            par(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, threads);
        }
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..5 {
            mapuot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
        }
        assert!(a.max_rel_diff(&b, 1e-6) < 1e-3, "threads={threads}");
    }

    #[test]
    fn mapuot_parallel_matches_serial() {
        for t in [1, 2, 3, 4, 8, 32] {
            check_parallel_matches_serial(mapuot_iterate, t, 1);
        }
    }

    #[test]
    fn coffee_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(coffee_iterate, t, 2);
        }
    }

    #[test]
    fn pot_parallel_matches_serial() {
        for t in [1, 2, 5, 16] {
            check_parallel_matches_serial(pot_iterate, t, 3);
        }
    }

    #[test]
    fn pool_backed_mapuot_matches_serial() {
        for t in [1, 2, 3, 8] {
            let p = Problem::random(23, 17, 0.7, 7);
            let pool = ThreadPool::new(t);
            let mut fcol = vec![0f32; 17];
            let mut acc = AccArena::padded(t, 17);
            let mut a = p.plan.clone();
            let mut cs_a = a.col_sums();
            let mut b = p.plan.clone();
            let mut cs_b = b.col_sums();
            for _ in 0..5 {
                mapuot_iterate_pool(&mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, &pool, &mut fcol, &mut acc);
                mapuot::iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi);
            }
            assert!(a.max_rel_diff(&b, 1e-6) < 1e-3, "pool threads={t}");
        }
    }

    #[test]
    fn more_threads_than_rows_is_safe() {
        let p = Problem::random(3, 5, 0.5, 4);
        let mut a = p.plan.clone();
        let mut cs = a.col_sums();
        mapuot_iterate(&mut a, &mut cs, &p.rpd, &p.cpd, p.fi, 64);
        assert!(a.as_slice().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn effective_threads_clamps() {
        assert_eq!(effective_threads(0, 10), 1);
        assert_eq!(effective_threads(16, 4), 4);
        assert_eq!(effective_threads(8, 100), 8);
    }

    #[test]
    fn into_variants_reuse_scratch_across_iterations() {
        let p = Problem::random(19, 13, 0.6, 5);
        let mut a = p.plan.clone();
        let mut cs_a = a.col_sums();
        let mut fcol = vec![0f32; 13];
        let mut rowsum = vec![0f32; 19];
        let mut acc = AccArena::padded(3, 13);
        let mut b = p.plan.clone();
        let mut cs_b = b.col_sums();
        for _ in 0..4 {
            coffee_iterate_into(
                &mut a, &mut cs_a, &p.rpd, &p.cpd, p.fi, 3, &mut fcol, &mut rowsum, &mut acc,
            );
            coffee_iterate(&mut b, &mut cs_b, &p.rpd, &p.cpd, p.fi, 3);
        }
        assert_eq!(a.as_slice(), b.as_slice());
        assert_eq!(cs_a, cs_b);
    }

    #[test]
    fn sparse_engines_bitmatch_partitioned_reference() {
        use crate::algo::sparse::{self, SparseProblem};
        let p = Problem::random(23, 17, 0.7, 13);
        let sp = SparseProblem::from_problem(&p, 1.0).unwrap();
        for t in [1usize, 2, 3, 8] {
            let part = NnzPartition::new(&sp.plan.row_ptr, t, t);
            let pool = ThreadPool::new(t);
            let mut scope_a = sp.plan.clone();
            let mut pool_b = sp.plan.clone();
            let mut ser_c = sp.plan.clone();
            let mut cs_a = scope_a.col_sums();
            let mut cs_b = pool_b.col_sums();
            let mut cs_c = ser_c.col_sums();
            let mut fcol = vec![0f32; 17];
            let mut inv = vec![0f32; 17];
            let mut acc_a = AccArena::padded(t, 17);
            let mut acc_b = AccArena::padded(t, 17);
            let mut acc_c = AccArena::padded(t, 17);
            let mut deltas = PaddedSlots::new(t);
            for _ in 0..4 {
                let da = sparse_mapuot_iterate_tracked(
                    &mut scope_a, &mut cs_a, &sp.rpd, &sp.cpd, sp.fi, &mut fcol, &mut inv,
                    &mut acc_a, &part,
                );
                let db = sparse_mapuot_iterate_pool_tracked(
                    &mut pool_b, &mut cs_b, &sp.rpd, &sp.cpd, sp.fi, &pool, &mut fcol, &mut inv,
                    &mut acc_b, &mut deltas, &part,
                );
                let dc = sparse_mapuot_iterate_partitioned_tracked(
                    &mut ser_c, &mut cs_c, &sp.rpd, &sp.cpd, sp.fi, &mut fcol, &mut inv,
                    &mut acc_c, &part,
                );
                assert_eq!(da.to_bits(), dc.to_bits(), "scope vs serial ref, t={t}");
                assert_eq!(db.to_bits(), dc.to_bits(), "pool vs serial ref, t={t}");
            }
            assert_eq!(scope_a.values, ser_c.values, "t={t}");
            assert_eq!(pool_b.values, ser_c.values, "t={t}");
            assert_eq!(cs_a, cs_c, "t={t}");
            assert_eq!(cs_b, cs_c, "t={t}");
        }
        // And the dense solver agrees on the same support (tolerance, not
        // bits — the colsum grouping differs).
        let mut dense = sp.plan.to_dense();
        let mut cs_d = dense.col_sums();
        let mut sp_serial = sp.plan.clone();
        let mut cs_s = sp_serial.col_sums();
        for _ in 0..4 {
            mapuot::iterate(&mut dense, &mut cs_d, &sp.rpd, &sp.cpd, sp.fi);
            sparse::iterate(&mut sp_serial, &mut cs_s, &sp.rpd, &sp.cpd, sp.fi);
        }
        assert!(sp_serial.to_dense().max_rel_diff(&dense, 1e-6) < 1e-3);
    }

    #[test]
    fn balanced_partition_uses_all_threads() {
        // m=9, t=8 used to produce 5 blocks (4x2 rows + a 1-row straggler);
        // the balanced partition gives all 8 threads work.
        let part = Partition::new(9, 8, usize::MAX);
        assert_eq!(part.blocks(), 8);
        assert_eq!(part.len(0), 2);
        for b in 1..8 {
            assert_eq!(part.len(b), 1);
        }
    }
}
