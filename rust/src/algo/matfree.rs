//! Materialization-free MAP-UOT: O(m+n) scaling-form solves over
//! on-the-fly Gibbs kernels.
//!
//! The paper's whole argument is that UOT iteration is bound by plan
//! traffic; the limit of that argument is to stop materializing the m×n
//! plan at all. Every MAP-UOT iterate is a cumulative diagonal rescaling
//! of the initial kernel, `plan_t = diag(u_t) · A · diag(v_t)`, so when
//! the kernel is *geometric* — `A_ij = exp(-c(x_i, y_j) / ε)` over point
//! clouds `x: m×d`, `y: n×d` — a solver can carry only the scaling
//! vectors `u, v` and regenerate kernel entries on demand (the rapid
//! kernel-evaluation line of work, arXiv:2306.13618). Resident state is
//! O(m + n): the scaling vectors, the carried marginal sums, and one
//! row-length generation buffer per thread. This opens shapes where the
//! dense and CSR backends cannot even allocate (a 10⁵×10⁵ plan is 40 GB;
//! its matfree state is under 2 MB).
//!
//! # The sweep
//!
//! One iteration is the same fused Algorithm 1 double-loop, expressed on
//! the scaling vectors. With `colsum` carried from the previous iteration:
//!
//! 1. `Factor_col[j] = (cpd[j] / colsum[j])^fi`; `v[j] *= Factor_col[j]`.
//! 2. Per row `i`: generate the scaled kernel row into the thread's panel
//!    buffer — `buf[j] = u[i] · exp(-c(x_i, y_j)/ε) · v[j]` — summing it
//!    on the fly (Computations I+II; costs are filled per
//!    [`KernelPolicy`]-sized column panel so the freshly written panel is
//!    still L1-resident when the exp pass reads it back).
//! 3. `Factor_row = (rpd[i] / Sum_row)^fi`; `u[i] *= Factor_row`; then the
//!    ordinary dense Computations III+IV primitive rescales the buffer by
//!    `Factor_row` while accumulating `NextSum_col` (and, tracked, the
//!    row's max element change via the same reciprocal-factor recovery as
//!    the dense kernels — the buffer value plays exactly the role of the
//!    post-column-rescale plan value).
//!
//! The buffer also leaves step 3 holding the *actual* new plan row, which
//! is what [`generate_plan_row`] / `SolverSession::matfree_materialize`
//! exploit for on-demand output. Marginal errors come for free: the
//! carried `NextSum_col` is the exact column-sum vector of the current
//! plan, and `rowsum[i] = Factor_row · Sum_row` its row sums (to one
//! rounding), so the convergence check costs O(m + n) — no extra
//! generation pass (the dense path pays a full M·N sweep per check).
//!
//! Per-row numerics are shared by every execution mode (the serial
//! reference, `thread::scope`, and the persistent pool run the same
//! per-block body over the same [`Partition`] with the same
//! block-ascending colsum reduction), so for any fixed partition all
//! three are **bit-identical** — the same contract as every other backend
//! (`rust/tests/prop_matfree.rs`).
//!
//! The exp evaluations run on the session's kernel backend
//! ([`crate::algo::kernels::Kernel::exp_scale_and_sum`]): libm `f32::exp`
//! on the scalar reference, the shared `util::simd::fast_exp` scheme on
//! the unrolled and AVX2 backends (within 1e-6 relative of libm across
//! the whole range, including gradual underflow). Non-temporal stores
//! never apply here — there is no O(m·n) buffer to stream.
//!
//! Trade-off: matfree swaps plan *bandwidth* for exp *compute*
//! (regenerate-vs-reload). A dense iteration moves 8 bytes per cell per
//! iteration at DRAM speed; matfree moves none but evaluates one exp per
//! cell. On hosts where a vectorized exp sustains a few elements/cycle,
//! break-even sits near the DRAM roofline — and past the shapes where the
//! dense plan exceeds memory, matfree is the only option
//! (`benches/ablation_matfree.rs` measures both regimes).

use std::ops::Range;
use std::sync::Arc;

use crate::algo::kernels::{Kernel, KernelKind, KernelPolicy, TileSpec};
use crate::algo::parallel;
use crate::algo::pool::{
    AccArena, AffinityHint, PaddedSlots, ParallelBackend, Partition, ThreadPool,
};
use crate::algo::scaling::factor;
use crate::error::{Error, Result};
use crate::util::matrix::CACHE_LINE;
use crate::util::XorShift;

/// Ground cost between points (the kernel is `exp(-cost / ε)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum CostKind {
    /// Squared Euclidean distance `‖x − y‖²` (the Gibbs kernel the
    /// applications use — no square root in the hot loop).
    SqEuclidean,
    /// Euclidean distance `‖x − y‖`.
    Euclidean,
}

impl CostKind {
    /// Parse from a CLI/config string.
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "sqeuclid" | "sqeuclidean" | "sq" | "l22" => Some(CostKind::SqEuclidean),
            "euclid" | "euclidean" | "l2" => Some(CostKind::Euclidean),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            CostKind::SqEuclidean => "sqeuclid",
            CostKind::Euclidean => "euclid",
        }
    }
}

/// A geometric UOT instance: two point clouds, a cost kind and kernel
/// bandwidth `ε` defining `A_ij = exp(-c(x_i, y_j)/ε)` implicitly, plus
/// the marginals — the matfree twin of [`crate::algo::Problem`], holding
/// O((m + n)·d) state where the dense twin holds O(m·n).
#[derive(Clone)]
pub struct GeomProblem {
    /// Row point cloud, row-major `m × d`.
    pub x: Vec<f32>,
    /// Column point cloud, row-major `n × d`.
    pub y: Vec<f32>,
    /// Point dimensionality.
    pub d: usize,
    /// Ground cost (the kernel is `exp(-cost/epsilon)`).
    pub cost: CostKind,
    /// Kernel bandwidth ε (entropic regularization strength).
    pub epsilon: f32,
    /// Row probability distribution (target row marginals), length M.
    pub rpd: Vec<f32>,
    /// Column probability distribution (target column marginals), length N.
    pub cpd: Vec<f32>,
    /// Relaxation exponent in `(0, 1]`.
    pub fi: f32,
}

impl GeomProblem {
    /// Validated constructor.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        x: Vec<f32>,
        y: Vec<f32>,
        d: usize,
        cost: CostKind,
        epsilon: f32,
        rpd: Vec<f32>,
        cpd: Vec<f32>,
        fi: f32,
    ) -> Result<Self> {
        if d == 0 {
            return Err(Error::InvalidProblem("point dimension d must be positive".into()));
        }
        if rpd.is_empty() || cpd.is_empty() {
            return Err(Error::InvalidProblem("geom problem dims must be positive".into()));
        }
        if x.len() != rpd.len() * d {
            return Err(Error::InvalidProblem(format!(
                "x has {} floats, expected m*d = {}*{}",
                x.len(),
                rpd.len(),
                d
            )));
        }
        if y.len() != cpd.len() * d {
            return Err(Error::InvalidProblem(format!(
                "y has {} floats, expected n*d = {}*{}",
                y.len(),
                cpd.len(),
                d
            )));
        }
        if x.iter().chain(y.iter()).any(|v| !v.is_finite()) {
            return Err(Error::InvalidProblem("point coordinates must be finite".into()));
        }
        if !(epsilon.is_finite() && epsilon > 0.0) {
            return Err(Error::InvalidProblem(format!(
                "epsilon {epsilon} must be finite and > 0"
            )));
        }
        if !(fi > 0.0 && fi <= 1.0) {
            return Err(Error::InvalidProblem(format!("fi={fi} outside (0, 1]")));
        }
        if rpd.iter().chain(cpd.iter()).any(|v| !v.is_finite() || *v <= 0.0) {
            return Err(Error::InvalidProblem("marginals must be positive and finite".into()));
        }
        Ok(Self { x, y, d, cost, epsilon, rpd, cpd, fi })
    }

    /// Synthetic instance: points uniform in the unit cube `[0, 1)^d`,
    /// marginals uniform in `[0.3, 1.7)` (the same ranges as
    /// [`crate::algo::Problem::random`], so behavior transfers). This is
    /// the generator the CLI `solve --matfree` and the matfree ablation
    /// bench use.
    pub fn random(
        m: usize,
        n: usize,
        d: usize,
        cost: CostKind,
        epsilon: f32,
        fi: f32,
        seed: u64,
    ) -> Self {
        let mut rng = XorShift::new(seed);
        let x = (0..m * d).map(|_| rng.next_f32()).collect();
        let y = (0..n * d).map(|_| rng.next_f32()).collect();
        let rpd = rng.uniform_vec(m, 0.3, 1.7);
        let cpd = rng.uniform_vec(n, 0.3, 1.7);
        Self { x, y, d, cost, epsilon, rpd, cpd, fi }
    }

    pub fn rows(&self) -> usize {
        self.rpd.len()
    }

    pub fn cols(&self) -> usize {
        self.cpd.len()
    }

    pub fn dim(&self) -> usize {
        self.d
    }

    /// Ground cost between row point `i` and column point `j` (scalar
    /// reference; the sweeps use the panel-filled form).
    pub fn cost_entry(&self, i: usize, j: usize) -> f32 {
        let xi = &self.x[i * self.d..(i + 1) * self.d];
        let yj = &self.y[j * self.d..(j + 1) * self.d];
        let mut s = 0f32;
        for k in 0..self.d {
            let t = xi[k] - yj[k];
            s += t * t;
        }
        match self.cost {
            CostKind::SqEuclidean => s,
            CostKind::Euclidean => s.sqrt(),
        }
    }

    /// One implicit kernel entry `A_ij = exp(-c(x_i, y_j)/ε)` (libm
    /// scalar reference — tests compare the fast-exp sweeps against it).
    pub fn kernel_entry(&self, i: usize, j: usize) -> f32 {
        (-self.cost_entry(i, j) / self.epsilon).exp()
    }

    /// Materialize the equivalent dense [`crate::algo::Problem`]
    /// (allocates the full M·N plan — tests and the ablation bench only;
    /// the entire point of this module is not doing this on solve paths).
    pub fn dense_problem(&self) -> crate::algo::Problem {
        crate::algo::Problem {
            plan: crate::util::Matrix::from_fn(self.rows(), self.cols(), |i, j| {
                self.kernel_entry(i, j)
            }),
            rpd: self.rpd.clone(),
            cpd: self.cpd.clone(),
            fi: self.fi,
        }
    }
}

impl std::fmt::Debug for GeomProblem {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GeomProblem")
            .field("m", &self.rows())
            .field("n", &self.cols())
            .field("d", &self.d)
            .field("cost", &self.cost.name())
            .field("epsilon", &self.epsilon)
            .field("fi", &self.fi)
            .finish()
    }
}

// ---------------------------------------------------------------------------
// Row generation
// ---------------------------------------------------------------------------

/// Fill `buf` with the costs `c(x_i, y_j)` for the column panel whose
/// points are `ys` (row-major, `buf.len() × d`). The d = 2/3 bodies are
/// unrolled by hand (the generic inner loop defeats vectorization at tiny
/// trip counts) with the same left-to-right summation order, so they are
/// bit-identical to the generic form.
#[inline]
pub(crate) fn fill_cost_row(buf: &mut [f32], xi: &[f32], ys: &[f32], d: usize, cost: CostKind) {
    debug_assert_eq!(buf.len() * d, ys.len());
    debug_assert_eq!(xi.len(), d);
    match d {
        2 => {
            let (x0, x1) = (xi[0], xi[1]);
            for (b, yj) in buf.iter_mut().zip(ys.chunks_exact(2)) {
                let t0 = x0 - yj[0];
                let t1 = x1 - yj[1];
                *b = t0 * t0 + t1 * t1;
            }
        }
        3 => {
            let (x0, x1, x2) = (xi[0], xi[1], xi[2]);
            for (b, yj) in buf.iter_mut().zip(ys.chunks_exact(3)) {
                let t0 = x0 - yj[0];
                let t1 = x1 - yj[1];
                let t2 = x2 - yj[2];
                *b = (t0 * t0 + t1 * t1) + t2 * t2;
            }
        }
        _ => {
            for (b, yj) in buf.iter_mut().zip(ys.chunks_exact(d)) {
                let mut s = 0f32;
                for k in 0..d {
                    let t = xi[k] - yj[k];
                    s += t * t;
                }
                *b = s;
            }
        }
    }
    if cost == CostKind::Euclidean {
        for b in buf {
            *b = b.sqrt();
        }
    }
}

/// Generate one scaled kernel row `buf[j] = scale · A_ij · v[j]` through
/// `kernel`, panel by panel (`tile` columns at a time; 0 = whole row so
/// the cost fill stays L1-resident for the exp pass), returning the row
/// sum.
#[inline]
#[allow(clippy::too_many_arguments)]
fn generate_row<K: Kernel>(
    k: &K,
    p: &GeomProblem,
    i: usize,
    scale: f32,
    v: &[f32],
    buf: &mut [f32],
    inv_eps: f32,
    tile: usize,
) -> f32 {
    let n = v.len();
    let d = p.d;
    let xi = &p.x[i * d..(i + 1) * d];
    let step = if tile == 0 { n } else { tile };
    let mut s = 0f32;
    let mut j0 = 0usize;
    while j0 < n {
        let j1 = (j0 + step).min(n);
        fill_cost_row(&mut buf[j0..j1], xi, &p.y[j0 * d..j1 * d], d, p.cost);
        s += k.exp_scale_and_sum(&mut buf[j0..j1], inv_eps, scale, &v[j0..j1]);
        j0 = j1;
    }
    s
}

/// Regenerate one *plan* row of the current iterate, `out[j] = u_i · A_ij
/// · v[j]`, under `policy` — the on-demand output path
/// (`SolverSession::matfree_plan_row` / `matfree_materialize`).
pub fn generate_plan_row(
    p: &GeomProblem,
    i: usize,
    u_i: f32,
    v: &[f32],
    out: &mut [f32],
    policy: &KernelPolicy,
) {
    use crate::algo::kernels::{ScalarKernel, UnrolledKernel};
    let inv_eps = 1.0 / p.epsilon;
    let tile = policy.tile_for(v.len()).unwrap_or(0);
    match policy.kind() {
        KernelKind::Scalar => {
            generate_row(&ScalarKernel, p, i, u_i, v, out, inv_eps, tile);
        }
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelKind::Avx2 => {
            generate_row(&crate::algo::kernels::AVX2_FMA_KERNEL, p, i, u_i, v, out, inv_eps, tile);
        }
        _ => {
            generate_row(&UnrolledKernel, p, i, u_i, v, out, inv_eps, tile);
        }
    }
}

/// The per-block body every matfree execution mode shares (the serial
/// reference calls it once per partition block sequentially; each thread
/// of the parallel engines over its own block): for each row of `rows`,
/// generate `buf[j] = u[i] · A_ij · v[j]` summing on the fly, fold the row
/// factor into `u` and the carried `rowsum`, then run the ordinary dense
/// Computations III+IV primitive over the buffer, accumulating
/// `NextSum_col` into `local`. Tracked (returns the block's max plan
/// element change) when `inv_fcol` is given — the buffer value stands in
/// for the post-column-rescale plan value, so the reciprocal-factor
/// recovery is exactly the dense kernels' trick.
///
/// Dispatches the kernel backend once per call and runs monomorphized,
/// mirroring `mapuot::fused_rows_opt`.
#[allow(clippy::too_many_arguments)]
pub(crate) fn matfree_rows_opt(
    p: &GeomProblem,
    rows: Range<usize>,
    u_block: &mut [f32],
    rowsum_block: &mut [f32],
    v: &[f32],
    inv_fcol: Option<&[f32]>,
    buf: &mut [f32],
    local: &mut [f32],
    policy: &KernelPolicy,
) -> f32 {
    use crate::algo::kernels::{ScalarKernel, UnrolledKernel};
    match policy.kind() {
        KernelKind::Scalar => matfree_rows_generic(
            &ScalarKernel, p, rows, u_block, rowsum_block, v, inv_fcol, buf, local, policy,
        ),
        #[cfg(any(target_arch = "x86", target_arch = "x86_64"))]
        KernelKind::Avx2 => matfree_rows_generic(
            &crate::algo::kernels::AVX2_FMA_KERNEL,
            p,
            rows,
            u_block,
            rowsum_block,
            v,
            inv_fcol,
            buf,
            local,
            policy,
        ),
        _ => matfree_rows_generic(
            &UnrolledKernel, p, rows, u_block, rowsum_block, v, inv_fcol, buf, local, policy,
        ),
    }
}

/// Monomorphized body of [`matfree_rows_opt`] — see its docs.
#[allow(clippy::too_many_arguments)]
fn matfree_rows_generic<K: Kernel>(
    k: &K,
    p: &GeomProblem,
    rows: Range<usize>,
    u_block: &mut [f32],
    rowsum_block: &mut [f32],
    v: &[f32],
    inv_fcol: Option<&[f32]>,
    buf: &mut [f32],
    local: &mut [f32],
    policy: &KernelPolicy,
) -> f32 {
    let n = v.len();
    debug_assert_eq!(u_block.len(), rows.len());
    debug_assert_eq!(rowsum_block.len(), rows.len());
    debug_assert!(buf.len() >= n && local.len() >= n);
    let buf = &mut buf[..n];
    let local = &mut local[..n];
    let inv_eps = 1.0 / p.epsilon;
    let tile = policy.tile_for(n).unwrap_or(0);
    let mut delta = 0f32;
    for (il, i) in rows.enumerate() {
        let ui = u_block[il];
        // Computations I+II over the regenerated row (u folded in at
        // generation, so `buf` plays the dense sweep's post-column-rescale
        // row and `s` is the true Sum_row of the current iterate).
        let s = generate_row(k, p, i, ui, v, buf, inv_eps, tile);
        // Computations III+IV: plain dense primitives over the buffer.
        // A zero row sum (u died, or every kernel entry underflowed at
        // this ε) guards to factor 0 exactly like the dense path.
        let fr = factor(p.rpd[i], s, p.fi);
        u_block[il] = ui * fr;
        rowsum_block[il] = fr * s;
        match inv_fcol {
            Some(iv) => {
                delta = delta.max(k.scale_by_scalar_and_accumulate_tracked(
                    buf, fr, iv, local, false,
                ));
            }
            // Never stream: the buffer is thread-local scratch re-read
            // next row — there is no O(m·n) store target in this backend.
            None => k.scale_by_scalar_and_accumulate(buf, fr, local, false),
        }
    }
    delta
}

/// Carried-marginal L-inf error: the sweep's `NextSum_col` is the exact
/// column-sum vector of the current plan and `rowsum` its row sums (one
/// rounding each), so the matfree convergence check is O(m + n) — no
/// generation pass. The float drift of the carried sums versus fresh sums
/// is bounded by the same per-sweep rounding the dense carried `colsum`
/// already accepts.
pub fn carried_marginal_error(rowsum: &[f32], colsum: &[f32], rpd: &[f32], cpd: &[f32]) -> f32 {
    debug_assert_eq!(rowsum.len(), rpd.len());
    debug_assert_eq!(colsum.len(), cpd.len());
    let row_err = rowsum
        .iter()
        .zip(rpd)
        .map(|(s, &t)| (s - t).abs())
        .fold(0f32, f32::max);
    let col_err = colsum
        .iter()
        .zip(cpd)
        .map(|(s, &t)| (s - t).abs())
        .fold(0f32, f32::max);
    row_err.max(col_err)
}

/// Seed-pass per-block body shared by the three seeding engines in
/// [`crate::algo::parallel`] (serial partitioned reference, scope, pool):
/// regenerate each row of `rows` as `u_i · A_ij · v_j` through the kernel
/// policy and accumulate its contribution to `NextSum_col` into `local`.
/// No factors are applied — this is the pure column-sum derivation that
/// seeds the carried `colsum` at the start of a solve (cold `u = v = 1`,
/// warm-started, or between ε-schedule rungs). Allocation-free.
pub(crate) fn matfree_seed_rows(
    p: &GeomProblem,
    rows: Range<usize>,
    u: &[f32],
    v: &[f32],
    buf: &mut [f32],
    local: &mut [f32],
    policy: &KernelPolicy,
) {
    let n = v.len();
    debug_assert!(buf.len() >= n && local.len() >= n);
    let buf = &mut buf[..n];
    local.fill(0.0);
    let local = &mut local[..n];
    for i in rows {
        generate_plan_row(p, i, u[i], v, buf, policy);
        for (acc, &w) in local.iter_mut().zip(buf.iter()) {
            *acc += w;
        }
    }
}

/// Hand scaling vectors from bandwidth `eps_old` to `eps_new` (ε-schedule
/// rung transition): the converged potentials satisfy `u = exp(φ/ε)`, so
/// holding the dual potential φ fixed across the bandwidth change means
/// `u ← u^(ε_old/ε_new)` (arXiv:2002.03293's coarse-to-fine handoff in
/// scaling form). Zero entries stay zero (a dead row/column stays dead);
/// the exponent is a no-op when the bandwidths match. Allocation-free.
pub fn carry_potentials(scale: &mut [f32], eps_old: f32, eps_new: f32) {
    if eps_old == eps_new {
        return;
    }
    let e = eps_old / eps_new;
    for s in scale.iter_mut() {
        *s = if *s > 0.0 { s.powf(e) } else { 0.0 };
    }
}

// ---------------------------------------------------------------------------
// MatfreeWorkspace
// ---------------------------------------------------------------------------

/// Scratch and engine for matfree solves — the materialization-free twin
/// of [`crate::algo::Workspace`]. Resident state is O(m + n) per thread:
/// column factors, their reciprocals, the per-thread `NextSum_col`
/// [`AccArena`], and one row-length generation panel per thread (a second
/// padded arena). Nothing here is ever O(m·n).
///
/// # Allocation contract
///
/// Construction and [`MatfreeWorkspace::ensure_shape`] growth may
/// allocate; [`MatfreeWorkspace::prepare`],
/// [`MatfreeWorkspace::seed_col_sums`], [`MatfreeWorkspace::iterate`] and
/// [`MatfreeWorkspace::iterate_tracked`] must not (the row partition is
/// rebuilt by value). Asserted by `rust/tests/alloc_free.rs` through the
/// session path, which also proves the headline claim: an
/// m = n = 16384 solve never performs an O(m·n)-sized allocation.
#[derive(Debug)]
pub struct MatfreeWorkspace {
    shape: (usize, usize),
    threads: usize,
    backend: ParallelBackend,
    /// Column rescaling factors (`Factor_col`), length N.
    fcol: Vec<f32>,
    /// Reciprocals of `fcol` (zero-guarded) for in-sweep delta tracking.
    inv_fcol: Vec<f32>,
    /// Per-thread row generation buffers (length N each, cache-line
    /// padded so adjacent workers never share a line).
    panels: AccArena,
    /// Per-thread `NextSum_col` partials, cache-line-padded.
    acc: AccArena,
    /// Per-thread tracked-delta maxima, one cache line each.
    delta_slots: PaddedSlots,
    /// Balanced row partition (dense-style even split — every matfree row
    /// costs the same n kernel evaluations), rebuilt per solve.
    part: Partition,
    /// The persistent execution engine (pool backend, `threads > 1`).
    pool: Option<Arc<ThreadPool>>,
    /// Kernel backend + generation panel width.
    policy: KernelPolicy,
}

impl MatfreeWorkspace {
    /// Workspace for `m × n` geometric problems with `threads` workers on
    /// the default pool backend (workers spawned here, once).
    pub fn new(m: usize, n: usize, threads: usize) -> Self {
        Self::with_backend(m, n, threads, ParallelBackend::Pool, AffinityHint::None)
    }

    /// Workspace with an explicit parallel backend and affinity hint.
    pub fn with_backend(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        affinity: AffinityHint,
    ) -> Self {
        let threads = threads.max(1);
        let pool = (threads > 1 && backend == ParallelBackend::Pool)
            .then(|| Arc::new(ThreadPool::with_affinity(threads, affinity)));
        let policy = KernelPolicy::for_shape(KernelKind::Auto, TileSpec::Auto, m, n);
        Self::with_engine(m, n, threads, backend, pool, policy)
    }

    /// Fully explicit assembly — the form
    /// [`crate::algo::SolverSession`] uses so one session's dense, sparse
    /// and matfree paths drive the same workers under the same resolved
    /// kernel policy.
    pub fn with_engine(
        m: usize,
        n: usize,
        threads: usize,
        backend: ParallelBackend,
        pool: Option<Arc<ThreadPool>>,
        policy: KernelPolicy,
    ) -> Self {
        let threads = match &pool {
            Some(p) => p.threads(),
            None => threads.max(1),
        };
        Self {
            shape: (m, n),
            threads,
            backend,
            fcol: vec![0f32; n],
            inv_fcol: vec![0f32; n],
            panels: AccArena::padded(threads, n),
            acc: AccArena::padded(threads, n),
            delta_slots: PaddedSlots::new(threads),
            part: Partition::new(m.max(1), threads, threads),
            pool,
            policy,
        }
    }

    /// Current `(rows, cols)` shape.
    pub fn shape(&self) -> (usize, usize) {
        self.shape
    }

    /// Worker threads this workspace is provisioned for.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Which parallel execution engine drives `threads > 1` iterations.
    pub fn backend(&self) -> ParallelBackend {
        self.backend
    }

    /// The persistent pool, when the pool backend is active.
    pub fn pool(&self) -> Option<&Arc<ThreadPool>> {
        self.pool.as_ref()
    }

    /// The kernel backend + panel policy driving generation.
    pub fn policy(&self) -> KernelPolicy {
        self.policy
    }

    /// The current row partition (valid after [`MatfreeWorkspace::prepare`]).
    pub fn partition(&self) -> &Partition {
        &self.part
    }

    /// Resize for a new shape. No-op (and allocation-free) when unchanged;
    /// growing past any previously seen size reallocates.
    pub fn ensure_shape(&mut self, m: usize, n: usize) {
        if self.shape == (m, n) {
            return;
        }
        self.shape = (m, n);
        self.fcol.resize(n, 0.0);
        self.inv_fcol.resize(n, 0.0);
        self.panels.ensure_cols(n);
        self.acc.ensure_cols(n);
    }

    /// Size scratch for an `m × n` problem and rebuild the row partition.
    /// Allocation-free for a same-shape problem; call once per solve.
    pub fn prepare(&mut self, m: usize, n: usize) {
        self.ensure_shape(m, n);
        let cap = self.acc.rows().min(self.panels.rows());
        self.part = Partition::new(m, self.threads, cap);
    }

    /// Seed the carried column sums of the current scaling state: one
    /// generation pass accumulating `Σ_i u_i · A_ij · v_j` — the matfree
    /// analogue of `Matrix::col_sums_into`, run once per solve (and per
    /// ε-schedule rung), allocation-free. Cold solves pass the all-ones
    /// vectors; warm starts and rung handoffs pass the carried scalings.
    ///
    /// Runs on this workspace's engine through the row partition (valid
    /// after [`MatfreeWorkspace::prepare`]): serial partitioned reference,
    /// scope, or the persistent pool — all three share the per-block body
    /// and the block-ascending reduction, so they are **bit-identical**
    /// for a fixed partition (`rust/tests/prop_warmstart.rs`).
    pub fn seed_col_sums(&mut self, p: &GeomProblem, u: &[f32], v: &[f32], out: &mut [f32]) {
        let (m, n) = (p.rows(), p.cols());
        debug_assert_eq!(self.shape, (m, n));
        debug_assert_eq!(u.len(), m);
        debug_assert_eq!(out.len(), n);
        if self.threads <= 1 {
            parallel::matfree_seed_partitioned(
                p,
                u,
                v,
                out,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        } else if let Some(pool) = &self.pool {
            parallel::matfree_seed_pool(
                p,
                u,
                v,
                out,
                pool,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        } else {
            parallel::matfree_seed_scope(
                p,
                u,
                v,
                out,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        }
    }

    /// One matfree iteration on this workspace's engine (serial partitioned
    /// reference, scope, or pool — all bit-identical for the same
    /// partition). `u`/`v`/`colsum`/`rowsum` are the carried solver state.
    pub fn iterate(
        &mut self,
        p: &GeomProblem,
        u: &mut [f32],
        v: &mut [f32],
        colsum: &mut [f32],
        rowsum: &mut [f32],
    ) {
        if self.threads <= 1 {
            parallel::matfree_iterate_partitioned(
                p,
                u,
                v,
                colsum,
                rowsum,
                &mut self.fcol,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        } else if let Some(pool) = &self.pool {
            parallel::matfree_iterate_pool(
                p,
                u,
                v,
                colsum,
                rowsum,
                pool,
                &mut self.fcol,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        } else {
            parallel::matfree_iterate_into(
                p,
                u,
                v,
                colsum,
                rowsum,
                &mut self.fcol,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            );
        }
    }

    /// [`MatfreeWorkspace::iterate`] with in-sweep delta tracking; returns
    /// the iteration's max plan element change.
    pub fn iterate_tracked(
        &mut self,
        p: &GeomProblem,
        u: &mut [f32],
        v: &mut [f32],
        colsum: &mut [f32],
        rowsum: &mut [f32],
    ) -> f32 {
        if self.threads <= 1 {
            parallel::matfree_iterate_partitioned_tracked(
                p,
                u,
                v,
                colsum,
                rowsum,
                &mut self.fcol,
                &mut self.inv_fcol,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            )
        } else if let Some(pool) = &self.pool {
            parallel::matfree_iterate_pool_tracked(
                p,
                u,
                v,
                colsum,
                rowsum,
                pool,
                &mut self.fcol,
                &mut self.inv_fcol,
                &mut self.panels,
                &mut self.acc,
                &mut self.delta_slots,
                &self.part,
                &self.policy,
            )
        } else {
            parallel::matfree_iterate_tracked(
                p,
                u,
                v,
                colsum,
                rowsum,
                &mut self.fcol,
                &mut self.inv_fcol,
                &mut self.panels,
                &mut self.acc,
                &self.part,
                &self.policy,
            )
        }
    }

    /// Bytes of resident workspace scratch (panel arenas included) — the
    /// figure the matfree ablation reports against the dense plan's
    /// `4·m·n`. Exact for the padded arenas.
    pub fn resident_bytes(&self) -> usize {
        let line_f32 = CACHE_LINE / 4;
        let arena = |rows: usize, cols: usize| rows * cols.div_ceil(line_f32) * CACHE_LINE;
        self.fcol.len() * 4
            + self.inv_fcol.len() * 4
            + arena(self.panels.rows(), self.panels.cols())
            + arena(self.acc.rows(), self.acc.cols())
            + self.delta_slots.slots() * CACHE_LINE
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::mapuot;

    #[test]
    fn validation_rejects_bad_inputs() {
        let ok = GeomProblem::new(
            vec![0.0; 6],
            vec![0.0; 9],
            3,
            CostKind::SqEuclidean,
            0.5,
            vec![1.0; 2],
            vec![1.0; 3],
            0.7,
        );
        assert!(ok.is_ok());
        let bad = |x: Vec<f32>, y: Vec<f32>, d, eps, rpd: Vec<f32>, cpd: Vec<f32>, fi| {
            GeomProblem::new(x, y, d, CostKind::SqEuclidean, eps, rpd, cpd, fi).is_err()
        };
        assert!(bad(vec![0.0; 5], vec![0.0; 9], 3, 0.5, vec![1.0; 2], vec![1.0; 3], 0.7)); // x len
        assert!(bad(vec![0.0; 6], vec![0.0; 8], 3, 0.5, vec![1.0; 2], vec![1.0; 3], 0.7)); // y len
        assert!(bad(vec![0.0; 6], vec![0.0; 9], 0, 0.5, vec![1.0; 2], vec![1.0; 3], 0.7)); // d = 0
        assert!(bad(vec![0.0; 6], vec![0.0; 9], 3, 0.0, vec![1.0; 2], vec![1.0; 3], 0.7)); // eps
        assert!(bad(vec![0.0; 6], vec![0.0; 9], 3, f32::NAN, vec![1.0; 2], vec![1.0; 3], 0.7));
        assert!(bad(vec![0.0; 6], vec![0.0; 9], 3, 0.5, vec![1.0; 2], vec![1.0; 3], 0.0)); // fi
        assert!(bad(vec![0.0; 6], vec![0.0; 9], 3, 0.5, vec![1.0, -1.0], vec![1.0; 3], 0.7));
        assert!(bad(vec![f32::NAN; 6], vec![0.0; 9], 3, 0.5, vec![1.0; 2], vec![1.0; 3], 0.7));
        assert!(bad(vec![], vec![0.0; 9], 3, 0.5, vec![], vec![1.0; 3], 0.7)); // m = 0
    }

    #[test]
    fn cost_parsing_and_entries() {
        assert_eq!(CostKind::parse("sqeuclid"), Some(CostKind::SqEuclidean));
        assert_eq!(CostKind::parse("L2"), Some(CostKind::Euclidean));
        assert_eq!(CostKind::parse("manhattan"), None);
        let p = GeomProblem::new(
            vec![0.0, 0.0, 3.0, 4.0],
            vec![0.0, 0.0],
            2,
            CostKind::SqEuclidean,
            1.0,
            vec![1.0; 2],
            vec![1.0],
            1.0,
        )
        .unwrap();
        assert_eq!(p.cost_entry(0, 0), 0.0);
        assert_eq!(p.cost_entry(1, 0), 25.0);
        let mut e = p.clone();
        e.cost = CostKind::Euclidean;
        assert_eq!(e.cost_entry(1, 0), 5.0);
        assert_eq!(p.kernel_entry(0, 0), 1.0);
        assert!((p.kernel_entry(1, 0) - (-25f32).exp()).abs() < 1e-12);
    }

    #[test]
    fn random_is_deterministic_and_in_range() {
        let a = GeomProblem::random(8, 6, 3, CostKind::SqEuclidean, 0.5, 0.7, 7);
        let b = GeomProblem::random(8, 6, 3, CostKind::SqEuclidean, 0.5, 0.7, 7);
        assert_eq!(a.x, b.x);
        assert_eq!(a.cpd, b.cpd);
        assert!(a.x.iter().all(|&v| (0.0..1.0).contains(&v)));
        assert!(GeomProblem::new(a.x, a.y, 3, a.cost, a.epsilon, a.rpd, a.cpd, a.fi).is_ok());
    }

    #[test]
    fn fill_cost_row_specializations_match_generic() {
        let mut rng = XorShift::new(5);
        for d in [1usize, 2, 3, 4, 7] {
            let n = 13;
            let xi: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
            let ys: Vec<f32> = (0..n * d).map(|_| rng.next_f32()).collect();
            for cost in [CostKind::SqEuclidean, CostKind::Euclidean] {
                let mut buf = vec![0f32; n];
                fill_cost_row(&mut buf, &xi, &ys, d, cost);
                for (j, &got) in buf.iter().enumerate() {
                    let mut s = 0f32;
                    for k in 0..d {
                        let t = xi[k] - ys[j * d + k];
                        s += t * t;
                    }
                    let want = if cost == CostKind::Euclidean { s.sqrt() } else { s };
                    assert_eq!(got.to_bits(), want.to_bits(), "d={d} j={j} {cost:?}");
                }
            }
        }
    }

    /// The serial matfree sweep matches the dense MAP-UOT kernel on the
    /// materialized problem, iteration by iteration (tolerance — the
    /// dense path rounds its stored plan where matfree re-derives entries
    /// from the scaling vectors).
    #[test]
    fn serial_iterations_track_the_dense_kernel() {
        for (m, n, d) in [(9usize, 7usize, 2usize), (16, 12, 3), (5, 40, 1)] {
            let p = GeomProblem::random(m, n, d, CostKind::SqEuclidean, 0.25, 0.7, (m + n) as u64);
            let dense = p.dense_problem();
            let mut plan = dense.plan.clone();
            let mut cs_dense = plan.col_sums();

            let mut ws = MatfreeWorkspace::new(m, n, 1);
            ws.prepare(m, n);
            let mut u = vec![1f32; m];
            let mut v = vec![1f32; n];
            let mut colsum = vec![0f32; n];
            let mut rowsum = vec![0f32; m];
            ws.seed_col_sums(&p, &u, &v, &mut colsum);
            for (a, b) in colsum.iter().zip(&cs_dense) {
                assert!((a - b).abs() <= 1e-5 * b.abs().max(1.0), "seed colsum {a} vs {b}");
            }
            for it in 0..8 {
                mapuot::iterate(&mut plan, &mut cs_dense, &p.rpd, &p.cpd, p.fi);
                ws.iterate(&p, &mut u, &mut v, &mut colsum, &mut rowsum);
                for (j, (a, b)) in colsum.iter().zip(&cs_dense).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4 * b.abs().max(1e-3),
                        "{m}x{n} it={it} col {j}: {a} vs {b}"
                    );
                }
            }
            // Materialized entries match the dense plan.
            let mut row = vec![0f32; n];
            for i in 0..m {
                generate_plan_row(&p, i, u[i], &v, &mut row, &ws.policy());
                for (j, &got) in row.iter().enumerate() {
                    let want = plan.get(i, j);
                    assert!(
                        (got - want).abs() <= 1e-4 * want.abs().max(1e-5),
                        "{m}x{n} plan[{i}][{j}]: {got} vs {want}"
                    );
                }
            }
            // Carried marginals match the materialized definition.
            let err = carried_marginal_error(&rowsum, &colsum, &p.rpd, &p.cpd);
            let dense_err = crate::algo::convergence::marginal_error(&plan, &p.rpd, &p.cpd);
            assert!((err - dense_err).abs() <= 1e-3 * dense_err.max(1e-2), "{err} vs {dense_err}");
        }
    }

    #[test]
    fn tracked_iteration_is_bit_identical_to_untracked() {
        let p = GeomProblem::random(14, 11, 3, CostKind::Euclidean, 0.5, 0.8, 9);
        let (m, n) = (14, 11);
        let mut ws_a = MatfreeWorkspace::new(m, n, 1);
        let mut ws_b = MatfreeWorkspace::new(m, n, 1);
        ws_a.prepare(m, n);
        ws_b.prepare(m, n);
        let (mut ua, mut va) = (vec![1f32; m], vec![1f32; n]);
        let (mut ub, mut vb) = (vec![1f32; m], vec![1f32; n]);
        let (mut ca, mut ra) = (vec![0f32; n], vec![0f32; m]);
        let (mut cb, mut rb) = (vec![0f32; n], vec![0f32; m]);
        ws_a.seed_col_sums(&p, &ua, &va, &mut ca);
        ws_b.seed_col_sums(&p, &ub, &vb, &mut cb);
        for _ in 0..5 {
            ws_a.iterate(&p, &mut ua, &mut va, &mut ca, &mut ra);
            let _ = ws_b.iterate_tracked(&p, &mut ub, &mut vb, &mut cb, &mut rb);
        }
        assert_eq!(ua, ub);
        assert_eq!(va, vb);
        assert_eq!(ca, cb);
        assert_eq!(ra, rb);
    }

    #[test]
    fn carry_potentials_holds_the_dual_fixed() {
        // u = exp(φ/ε): carrying ε 0.8 → 0.2 must four-fold the log.
        let mut u = [1.0f32, (2.0f32).exp(), 0.0];
        carry_potentials(&mut u, 0.8, 0.2);
        assert_eq!(u[0], 1.0);
        assert!((u[1] - (8.0f32).exp()).abs() <= 1e-3 * (8.0f32).exp());
        assert_eq!(u[2], 0.0, "dead entries stay dead");
        // Same bandwidth: bitwise no-op.
        let mut w = [0.37f32, 1.91];
        let before = w;
        carry_potentials(&mut w, 0.5, 0.5);
        assert_eq!(w, before);
    }

    #[test]
    fn seed_col_sums_accepts_non_uniform_scalings() {
        // Seeding with (u, v) must equal the materialized column sums of
        // diag(u)·A·diag(v), not just the all-ones special case.
        let p = GeomProblem::random(9, 7, 2, CostKind::SqEuclidean, 0.4, 0.7, 21);
        let (m, n) = (9, 7);
        let u: Vec<f32> = (0..m).map(|i| 0.5 + 0.25 * i as f32).collect();
        let v: Vec<f32> = (0..n).map(|j| 2.0 - 0.2 * j as f32).collect();
        let mut ws = MatfreeWorkspace::new(m, n, 1);
        ws.prepare(m, n);
        let mut colsum = vec![0f32; n];
        ws.seed_col_sums(&p, &u, &v, &mut colsum);
        let mut row = vec![0f32; n];
        let mut want = vec![0f32; n];
        for i in 0..m {
            generate_plan_row(&p, i, u[i], &v, &mut row, &ws.policy());
            for (w, &x) in want.iter_mut().zip(row.iter()) {
                *w += x;
            }
        }
        for (j, (a, b)) in colsum.iter().zip(&want).enumerate() {
            assert!((a - b).abs() <= 1e-5 * b.abs().max(1e-6), "col {j}: {a} vs {b}");
        }
    }

    #[test]
    fn resident_state_is_o_m_plus_n() {
        let ws = MatfreeWorkspace::new(4096, 4096, 2);
        // Workspace scratch stays a tiny multiple of (m + n), nowhere near
        // the 64 MiB dense plan.
        assert!(ws.resident_bytes() < 4096 * 64, "{}", ws.resident_bytes());
    }
}
