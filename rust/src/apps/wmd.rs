//! Sinkhorn Word Mover's Distance (paper §2.3; Kusner et al. 2015,
//! Tithi & Petrini 2021 — the PIUMA work COFFEE built on).
//!
//! Distance between two documents = entropic OT cost between their
//! normalized bag-of-words measures over word-embedding space. Synthetic
//! vocabulary embeddings (topic clusters) stand in for word2vec; documents
//! sample words from topic mixtures, so same-topic documents must come out
//! closer than cross-topic ones — the qualitative check Kusner's paper
//! motivates WMD with.

use crate::algo::balancing;
use crate::apps::AppReport;
use crate::util::{Matrix, Timer, XorShift};

/// Synthetic embedded vocabulary: `topics` Gaussian clusters in `dim`-D.
pub struct Vocabulary {
    pub embeddings: Vec<Vec<f32>>,
    pub topic_of: Vec<usize>,
}

pub fn make_vocabulary(words: usize, topics: usize, dim: usize, seed: u64) -> Vocabulary {
    let mut rng = XorShift::new(seed);
    let centers: Vec<Vec<f32>> = (0..topics)
        .map(|_| (0..dim).map(|_| rng.uniform(-2.0, 2.0)).collect())
        .collect();
    let mut embeddings = Vec::with_capacity(words);
    let mut topic_of = Vec::with_capacity(words);
    for w in 0..words {
        let t = w % topics;
        embeddings.push(centers[t].iter().map(|c| c + 0.3 * rng.normal()).collect());
        topic_of.push(t);
    }
    Vocabulary { embeddings, topic_of }
}

/// A document: word frequencies over the vocabulary (normalized).
pub fn make_document(vocab: &Vocabulary, topic: usize, len: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShift::new(seed);
    let words = vocab.embeddings.len();
    let mut freq = vec![0f32; words];
    for _ in 0..len {
        // 80% in-topic, 20% anywhere.
        let w = loop {
            let cand = rng.below(words);
            if vocab.topic_of[cand] == topic || rng.next_f32() < 0.2 {
                break cand;
            }
        };
        freq[w] += 1.0;
    }
    let total: f32 = freq.iter().sum();
    for f in &mut freq {
        *f = (*f + 1e-6) / (total + 1e-6 * words as f32);
    }
    freq
}

/// Sinkhorn-WMD between two documents over `vocab` (cost = squared
/// embedding distance), using the fused balanced-Sinkhorn path.
pub fn wmd(vocab: &Vocabulary, doc_a: &[f32], doc_b: &[f32], eps: f32, iters: usize) -> f32 {
    let n = vocab.embeddings.len();
    let cost = Matrix::from_fn(n, n, |i, j| {
        vocab.embeddings[i]
            .iter()
            .zip(&vocab.embeddings[j])
            .map(|(a, b)| (a - b).powi(2))
            .sum()
    });
    let (_, d) = balancing::sinkhorn_distance(&cost, doc_a, doc_b, eps, iters);
    d
}

/// Benchmark-style run: pairwise WMD over a small synthetic corpus,
/// reporting nearest-neighbour topic accuracy + timing.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub words: usize,
    pub topics: usize,
    pub dim: usize,
    pub docs_per_topic: usize,
    pub eps: f32,
    pub iters: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { words: 128, topics: 4, dim: 8, docs_per_topic: 3, eps: 0.5, iters: 50 }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct Output {
    /// 1-NN topic classification accuracy under WMD.
    pub knn_accuracy: f64,
    pub report: AppReport,
}

pub fn run(cfg: Config) -> Output {
    let total = Timer::start();
    let vocab = make_vocabulary(cfg.words, cfg.topics, cfg.dim, 5);
    let docs: Vec<(usize, Vec<f32>)> = (0..cfg.topics)
        .flat_map(|t| {
            (0..cfg.docs_per_topic)
                .map(move |k| (t, (t * 1000 + k) as u64))
        })
        .map(|(t, seed)| (t, make_document(&vocab, t, 60, seed)))
        .collect();

    let uot = Timer::start();
    let nd = docs.len();
    let mut dist = vec![vec![0f32; nd]; nd];
    for i in 0..nd {
        for j in (i + 1)..nd {
            let d = wmd(&vocab, &docs[i].1, &docs[j].1, cfg.eps, cfg.iters);
            dist[i][j] = d;
            dist[j][i] = d;
        }
    }
    let uot_s = uot.elapsed().as_secs_f64();

    let mut correct = 0;
    for i in 0..nd {
        let nn = (0..nd)
            .filter(|&j| j != i)
            .min_by(|&a, &b| dist[i][a].partial_cmp(&dist[i][b]).expect("finite"))
            .expect("nd > 1");
        if docs[nn].0 == docs[i].0 {
            correct += 1;
        }
    }

    Output {
        knn_accuracy: correct as f64 / nd as f64,
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: cfg.iters * nd * (nd - 1) / 2,
            solver: crate::algo::SolverKind::MapUot,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_topic_docs_are_closer() {
        let vocab = make_vocabulary(64, 3, 6, 1);
        let a1 = make_document(&vocab, 0, 50, 10);
        let a2 = make_document(&vocab, 0, 50, 11);
        let b = make_document(&vocab, 1, 50, 12);
        let d_same = wmd(&vocab, &a1, &a2, 0.5, 40);
        let d_diff = wmd(&vocab, &a1, &b, 0.5, 40);
        assert!(d_same < d_diff, "same={d_same} diff={d_diff}");
    }

    #[test]
    fn knn_beats_chance() {
        let out = run(Config { words: 64, docs_per_topic: 3, ..Default::default() });
        assert!(out.knn_accuracy > 0.5, "acc={}", out.knn_accuracy); // chance 0.25-ish
    }

    #[test]
    fn wmd_is_symmetric_and_nonnegative() {
        let vocab = make_vocabulary(48, 2, 4, 2);
        let a = make_document(&vocab, 0, 40, 1);
        let b = make_document(&vocab, 1, 40, 2);
        let d1 = wmd(&vocab, &a, &b, 0.5, 40);
        let d2 = wmd(&vocab, &b, &a, 0.5, 40);
        assert!(d1 >= 0.0);
        assert!((d1 - d2).abs() < 1e-3 * d1.max(1.0), "{d1} vs {d2}");
    }
}
