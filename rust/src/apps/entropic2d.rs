//! 2-D entropic UOT (paper §2.2, Fig. 2 second app; Pham et al. 2020).
//!
//! Transport between two 2-D histograms (images as measures over a pixel
//! grid): the plan lives over `grid² × grid²` bin pairs, the cost is the
//! squared grid distance, and the marginals are the two images' mass
//! distributions. Unbalanced (fi < 1) because the images carry different
//! total mass — the canonical UOT use case.

use crate::algo::{Problem, SolverKind, SolverSession, StopRule};
use crate::apps::AppReport;
use crate::util::{Matrix, Timer, XorShift};

/// A 2-D histogram (mass over a `grid × grid` lattice).
pub fn synthetic_histogram(grid: usize, blobs: usize, seed: u64) -> Vec<f32> {
    let mut rng = XorShift::new(seed);
    let centers: Vec<(f32, f32, f32)> = (0..blobs)
        .map(|_| {
            (
                rng.uniform(0.15, 0.85) * grid as f32,
                rng.uniform(0.15, 0.85) * grid as f32,
                rng.uniform(0.05, 0.2) * grid as f32, // width
            )
        })
        .collect();
    let mut h = vec![0f32; grid * grid];
    for y in 0..grid {
        for x in 0..grid {
            let mut v = 1e-4; // positive background mass
            for &(cx, cy, w) in &centers {
                let d2 = (x as f32 - cx).powi(2) + (y as f32 - cy).powi(2);
                v += (-d2 / (2.0 * w * w)).exp();
            }
            h[y * grid + x] = v;
        }
    }
    h
}

/// Run config: the UOT problem is `grid² × grid²`.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub grid: usize,
    pub eps: f32,
    pub fi: f32,
    pub solver: SolverKind,
    pub threads: usize,
    pub max_iter: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { grid: 16, eps: 8.0, fi: 0.7, solver: SolverKind::MapUot, threads: 1, max_iter: 300 }
    }
}

/// Output: transported-mass diagnostics + timing.
#[derive(Debug, Clone, Copy)]
pub struct Output {
    /// Total plan mass (between the two histograms' totals for UOT).
    pub plan_mass: f32,
    /// Mean transport distance weighted by plan mass (grid units).
    pub mean_distance: f32,
    pub report: AppReport,
}

/// Run 2-D entropic UOT between two synthetic histograms.
pub fn run(cfg: Config) -> Output {
    let total = Timer::start();
    let g = cfg.grid;
    let n = g * g;
    let src = synthetic_histogram(g, 3, 31);
    let dst = synthetic_histogram(g, 4, 77);

    // Gibbs kernel over squared grid distances.
    let coord = |k: usize| ((k % g) as f32, (k / g) as f32);
    let plan0 = Matrix::from_fn(n, n, |a, b| {
        let (ax, ay) = coord(a);
        let (bx, by) = coord(b);
        let d2 = (ax - bx).powi(2) + (ay - by).powi(2);
        (-d2 / cfg.eps).exp()
    });
    let problem = Problem { plan: plan0, rpd: src.clone(), cpd: dst.clone(), fi: cfg.fi };

    let uot = Timer::start();
    let mut session = SolverSession::builder(cfg.solver)
        .threads(cfg.threads)
        .stop(StopRule { tol: 0.0, delta_tol: 1e-7, max_iter: cfg.max_iter })
        .build(&problem);
    let solve_report = session.solve(&problem).expect("observer-free solve");
    let plan = session.into_plan();
    let uot_s = uot.elapsed().as_secs_f64();

    let mut mass = 0f64;
    let mut wdist = 0f64;
    for a in 0..n {
        let (ax, ay) = coord(a);
        for (b, &v) in plan.row(a).iter().enumerate() {
            let (bx, by) = coord(b);
            mass += v as f64;
            wdist += v as f64 * (((ax - bx).powi(2) + (ay - by).powi(2)) as f64).sqrt();
        }
    }

    Output {
        plan_mass: mass as f32,
        mean_distance: if mass > 0.0 { (wdist / mass) as f32 } else { 0.0 },
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: solve_report.iters,
            solver: cfg.solver,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_stays_local_for_small_eps() {
        let out = run(Config { grid: 10, eps: 2.0, max_iter: 100, ..Default::default() });
        // With a tight kernel, mass should move only a few grid cells.
        assert!(out.mean_distance < 4.0, "mean distance {}", out.mean_distance);
        assert!(out.plan_mass > 0.0);
    }

    #[test]
    fn unbalanced_mass_between_marginal_totals() {
        let cfg = Config { grid: 8, max_iter: 200, ..Default::default() };
        let out = run(cfg);
        let src: f32 = synthetic_histogram(8, 3, 31).iter().sum();
        let dst: f32 = synthetic_histogram(8, 4, 77).iter().sum();
        let (lo, hi) = (src.min(dst), src.max(dst));
        // UOT relaxes marginals: total plan mass lands in the vicinity of
        // the two totals rather than matching either exactly.
        assert!(
            out.plan_mass > 0.3 * lo && out.plan_mass < 2.0 * hi,
            "mass {} vs totals {src}/{dst}",
            out.plan_mass
        );
    }

    #[test]
    fn uot_dominates_runtime() {
        let out = run(Config { grid: 16, max_iter: 300, ..Default::default() });
        assert!(out.report.uot_share() > 0.5, "share {}", out.report.uot_share());
    }
}
