//! Optimal-transport domain adaptation (paper §2.2; Courty/Flamary et al.).
//!
//! Source samples carry labels; the target distribution is the source
//! shifted/rotated. UOT aligns the clouds, labels propagate through the
//! plan, and we score transfer accuracy — the paper's Fig. 2 uses this app
//! to show UOT's share of end-to-end time growing with the matrix size.

use crate::algo::{Problem, SolverKind, SolverSession, StopRule};
use crate::apps::AppReport;
use crate::util::{Timer, XorShift};

/// One labeled 3-D point cloud pair (source labeled, target shifted).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub source: Vec<[f32; 3]>,
    pub labels: Vec<usize>,
    pub target: Vec<[f32; 3]>,
    /// Ground-truth target labels (same generative cluster).
    pub target_labels: Vec<usize>,
    pub classes: usize,
}

/// Gaussian-cluster dataset with a global shift + per-class jitter between
/// domains.
pub fn make_dataset(n_per_class: usize, classes: usize, seed: u64) -> Dataset {
    let mut rng = XorShift::new(seed);
    let mut centers = Vec::new();
    for _ in 0..classes {
        centers.push([rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0), rng.uniform(-3.0, 3.0)]);
    }
    let shift = [rng.uniform(0.5, 1.0), rng.uniform(-1.0, -0.5), rng.uniform(0.2, 0.6)];
    let mut source = Vec::new();
    let mut labels = Vec::new();
    let mut target = Vec::new();
    let mut target_labels = Vec::new();
    for (c, center) in centers.iter().enumerate() {
        for _ in 0..n_per_class {
            source.push(std::array::from_fn(|k| center[k] + 0.4 * rng.normal()));
            labels.push(c);
            target.push(std::array::from_fn(|k| center[k] + shift[k] + 0.4 * rng.normal()));
            target_labels.push(c);
        }
    }
    Dataset { source, labels, target, target_labels, classes }
}

/// Run config.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub n_per_class: usize,
    pub classes: usize,
    pub eps: f32,
    pub fi: f32,
    pub solver: SolverKind,
    pub threads: usize,
    pub max_iter: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            n_per_class: 64,
            classes: 4,
            eps: 0.5,
            fi: 0.9,
            solver: SolverKind::MapUot,
            threads: 1,
            max_iter: 300,
            seed: 3,
        }
    }
}

/// Output: label-transfer accuracy + timing.
#[derive(Debug, Clone, Copy)]
pub struct Output {
    pub accuracy: f64,
    pub report: AppReport,
}

/// Run adaptation: solve UOT between clouds, transfer labels by plan-mass
/// voting, score against ground truth.
pub fn run(cfg: Config) -> Output {
    let total = Timer::start();
    let ds = make_dataset(cfg.n_per_class, cfg.classes, cfg.seed);
    let problem = Problem::from_point_clouds(&ds.source, &ds.target, cfg.eps, cfg.fi);

    let uot = Timer::start();
    let mut session = SolverSession::builder(cfg.solver)
        .threads(cfg.threads)
        .stop(StopRule { max_iter: cfg.max_iter, ..Default::default() })
        .build(&problem);
    let solve_report = session.solve(&problem).expect("observer-free solve");
    let plan = session.into_plan();
    let uot_s = uot.elapsed().as_secs_f64();

    // Label transfer: target j takes the argmax over classes of the plan
    // mass arriving from source points of that class.
    let n_t = ds.target.len();
    let mut correct = 0usize;
    for j in 0..n_t {
        let mut votes = vec![0f64; ds.classes];
        for i in 0..ds.source.len() {
            votes[ds.labels[i]] += plan.get(i, j) as f64;
        }
        let pred = votes
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
            .map(|(c, _)| c)
            .expect("non-empty");
        if pred == ds.target_labels[j] {
            correct += 1;
        }
    }

    Output {
        accuracy: correct as f64 / n_t as f64,
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: solve_report.iters,
            solver: cfg.solver,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptation_beats_chance() {
        let out = run(Config { n_per_class: 32, classes: 4, ..Default::default() });
        // Chance = 0.25; well-separated shifted clusters should transfer well.
        assert!(out.accuracy > 0.6, "accuracy={}", out.accuracy);
    }

    #[test]
    fn solver_choice_does_not_change_accuracy() {
        let base = Config { n_per_class: 24, classes: 3, ..Default::default() };
        let a = run(Config { solver: SolverKind::MapUot, ..base });
        let b = run(Config { solver: SolverKind::Pot, ..base });
        assert!((a.accuracy - b.accuracy).abs() < 1e-9);
    }

    #[test]
    fn uot_share_grows_with_problem_size() {
        let small = run(Config { n_per_class: 16, ..Default::default() });
        let large = run(Config { n_per_class: 96, ..Default::default() });
        assert!(
            large.report.uot_share() >= small.report.uot_share() * 0.8,
            "small={} large={}",
            small.report.uot_share(),
            large.report.uot_share()
        );
    }
}
