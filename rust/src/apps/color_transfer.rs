//! Image color transfer via UOT (paper §5.5, Fig. 17; Ferradans et al.).
//!
//! Pipeline: sample the two images' RGB clouds into palettes → Gibbs
//! kernel between palettes → UOT solve → barycentric projection maps the
//! source palette into the target's color distribution → repaint pixels
//! by nearest palette entry. Images are procedural (gradient + structured
//! noise), matching the paper's use of photographs only as RGB histogram
//! sources.

use crate::algo::{Problem, SolverKind, SolverSession, StopRule};
use crate::apps::AppReport;
use crate::util::{Timer, XorShift};

/// A synthetic RGB image (row-major pixels in [0,1]).
#[derive(Debug, Clone)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub pixels: Vec<[f32; 3]>,
}

impl Image {
    /// Procedural image: two-corner gradient + per-channel sinusoidal
    /// texture + noise, parameterized by `seed` so source/target images
    /// have distinct color distributions.
    pub fn synthetic(width: usize, height: usize, seed: u64) -> Self {
        let mut rng = XorShift::new(seed);
        let (base, tint): ([f32; 3], [f32; 3]) = (
            [rng.uniform(0.1, 0.9), rng.uniform(0.1, 0.9), rng.uniform(0.1, 0.9)],
            [rng.uniform(0.1, 0.9), rng.uniform(0.1, 0.9), rng.uniform(0.1, 0.9)],
        );
        let fx = rng.uniform(2.0, 7.0);
        let fy = rng.uniform(2.0, 7.0);
        let mut pixels = Vec::with_capacity(width * height);
        for y in 0..height {
            for x in 0..width {
                let u = x as f32 / width as f32;
                let v = y as f32 / height as f32;
                let wave = 0.5 + 0.5 * (fx * u * std::f32::consts::PI).sin() * (fy * v * std::f32::consts::PI).cos();
                let noise = rng.uniform(-0.05, 0.05);
                let px = std::array::from_fn(|c| {
                    (base[c] * (1.0 - u * v) + tint[c] * u * v * wave + noise).clamp(0.0, 1.0)
                });
                pixels.push(px);
            }
        }
        Self { width, height, pixels }
    }

    /// Uniformly sample `k` pixels as a color palette.
    pub fn palette(&self, k: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut rng = XorShift::new(seed ^ 0xC010_55AA_1234_5678);
        (0..k).map(|_| self.pixels[rng.below(self.pixels.len())]).collect()
    }
}

/// Configuration of one color-transfer run.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub width: usize,
    pub height: usize,
    /// Palette size: the UOT problem is `palette × palette`.
    pub palette: usize,
    pub eps: f32,
    pub fi: f32,
    pub solver: SolverKind,
    pub threads: usize,
    pub max_iter: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            width: 192,
            height: 128,
            palette: 256,
            eps: 0.05,
            fi: 0.9,
            solver: SolverKind::MapUot,
            threads: 1,
            max_iter: 200,
        }
    }
}

/// Quantized-grid nearest-palette lookup: each of `g³` RGB bins stores the
/// index of the palette entry closest to the bin center (exact for the
/// repaint's purposes at g = 16: bin diagonal ≪ typical palette spacing).
struct NearestLut {
    g: usize,
    bins: Vec<u32>,
}

impl NearestLut {
    fn build(palette: &[[f32; 3]], g: usize) -> Self {
        let mut bins = vec![0u32; g * g * g];
        for r in 0..g {
            for gg in 0..g {
                for b in 0..g {
                    let center = [
                        (r as f32 + 0.5) / g as f32,
                        (gg as f32 + 0.5) / g as f32,
                        (b as f32 + 0.5) / g as f32,
                    ];
                    let mut best = (f32::MAX, 0u32);
                    for (i, q) in palette.iter().enumerate() {
                        let d: f32 = (0..3).map(|c| (center[c] - q[c]).powi(2)).sum();
                        if d < best.0 {
                            best = (d, i as u32);
                        }
                    }
                    bins[(r * g + gg) * g + b] = best.1;
                }
            }
        }
        Self { g, bins }
    }

    #[inline]
    fn nearest(&self, p: &[f32; 3]) -> usize {
        let q = |v: f32| {
            ((v * self.g as f32) as usize).min(self.g - 1)
        };
        self.bins[(q(p[0]) * self.g + q(p[1])) * self.g + q(p[2])] as usize
    }
}

/// Output of a run: the recolored source image + timing report.
#[derive(Debug)]
pub struct Output {
    pub mapped_palette: Vec<[f32; 3]>,
    pub recolored: Image,
    pub report: AppReport,
}

/// Run the full pipeline.
///
/// Image synthesis happens before the timed window: it substitutes for the
/// paper's image *loading* (cheap I/O), so timing it would mis-state the
/// Fig. 2/17 breakdown. The timed pipeline is: palette extraction → Gibbs
/// kernel → UOT solve (to the tight tolerance the paper's applications
/// use) → barycentric map → repaint.
pub fn run(cfg: Config) -> Output {
    let src = Image::synthetic(cfg.width, cfg.height, 11);
    let dst = Image::synthetic(cfg.width, cfg.height, 97);

    let total = Timer::start();
    let xs = src.palette(cfg.palette, 1);
    let ys = dst.palette(cfg.palette, 2);

    let mut problem = Problem::from_point_clouds(&xs, &ys, cfg.eps, cfg.fi);
    problem.fi = cfg.fi;

    let uot = Timer::start();
    let mut session = SolverSession::builder(cfg.solver)
        .threads(cfg.threads)
        // Fixed iteration budget, like the paper's performance figures
        // (no early exit — the budget IS the workload definition).
        .stop(StopRule { tol: 0.0, delta_tol: 0.0, max_iter: cfg.max_iter })
        .build(&problem);
    let solve_report = session.solve(&problem).expect("observer-free solve");
    let plan = session.into_plan();
    let uot_s = uot.elapsed().as_secs_f64();

    // Barycentric projection: palette_i -> sum_j plan_ij * y_j / rowsum_i.
    let mapped_palette: Vec<[f32; 3]> = (0..cfg.palette)
        .map(|i| {
            let row = plan.row(i);
            let rs: f32 = row.iter().sum();
            if rs <= 0.0 {
                return xs[i];
            }
            std::array::from_fn(|c| row.iter().zip(&ys).map(|(&w, y)| w * y[c]).sum::<f32>() / rs)
        })
        .collect();

    // Repaint: each pixel adopts the mapped color of its nearest palette
    // entry. Nearest lookup goes through a quantized RGB grid LUT so the
    // repaint is O(pixels) and the pipeline stays UOT-dominated (Fig. 2),
    // as in the paper's implementation.
    let lut = NearestLut::build(&xs, 16);
    let recolored_pixels: Vec<[f32; 3]> = src
        .pixels
        .iter()
        .map(|p| mapped_palette[lut.nearest(p)])
        .collect();

    Output {
        mapped_palette,
        recolored: Image { width: src.width, height: src.height, pixels: recolored_pixels },
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: solve_report.iters,
            solver: cfg.solver,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_produces_valid_colors() {
        let out = run(Config { width: 32, height: 32, palette: 32, max_iter: 64, ..Default::default() });
        assert_eq!(out.recolored.pixels.len(), 32 * 32);
        for p in &out.recolored.pixels {
            for c in p {
                assert!((0.0..=1.0).contains(c), "{c}");
            }
        }
        assert!(out.report.uot_s <= out.report.total_s);
        assert!(out.report.uot_share() > 0.0);
    }

    #[test]
    fn mapped_palette_moves_toward_target_distribution() {
        let cfg = Config { width: 48, height: 48, palette: 64, max_iter: 200, ..Default::default() };
        let out = run(cfg);
        // The mapped palette's mean should sit between source and pure
        // target means — mass actually transported.
        let src = Image::synthetic(cfg.width, cfg.height, 11);
        let xs = src.palette(cfg.palette, 1);
        let mean = |ps: &[[f32; 3]]| -> [f32; 3] {
            let mut m = [0f32; 3];
            for p in ps {
                for c in 0..3 {
                    m[c] += p[c] / ps.len() as f32;
                }
            }
            m
        };
        let src_mean = mean(&xs);
        let mapped_mean = mean(&out.mapped_palette);
        let moved: f32 = (0..3).map(|c| (mapped_mean[c] - src_mean[c]).abs()).sum();
        assert!(moved > 1e-3, "palette did not move: {moved}");
    }

    #[test]
    fn all_solvers_give_same_recoloring() {
        let base = Config { width: 24, height: 24, palette: 32, max_iter: 100, ..Default::default() };
        let a = run(Config { solver: SolverKind::MapUot, ..base });
        let b = run(Config { solver: SolverKind::Pot, ..base });
        for (x, y) in a.mapped_palette.iter().zip(&b.mapped_palette) {
            for c in 0..3 {
                assert!((x[c] - y[c]).abs() < 1e-3, "{x:?} vs {y:?}");
            }
        }
    }
}
