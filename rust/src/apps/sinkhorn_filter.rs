//! Fast Sinkhorn filter for non-rigid shape correspondence (paper §2.2;
//! Pai et al., CVPR 2021).
//!
//! Two synthetic "shapes" (deformed circles in 3-D) are matched by
//! Sinkhorn-filtering their spectral-feature affinity matrix: UOT turns a
//! noisy soft correspondence into a near-permutation. Quality metric:
//! fraction of points whose argmax match is within `k` of the ground-truth
//! correspondence along the curve.

use crate::algo::{Problem, SolverKind, SolverSession, StopRule};
use crate::apps::AppReport;
use crate::util::{Timer, XorShift};

/// Sampled shape: `n` points along a deformed closed curve.
pub fn make_shape(n: usize, deform: f32, seed: u64) -> Vec<[f32; 3]> {
    let mut rng = XorShift::new(seed);
    let (a3, a5) = (deform * rng.uniform(0.5, 1.0), deform * rng.uniform(0.2, 0.6));
    (0..n)
        .map(|i| {
            let t = i as f32 / n as f32 * 2.0 * std::f32::consts::PI;
            let r = 1.0 + a3 * (3.0 * t).sin() + a5 * (5.0 * t).cos();
            [r * t.cos(), r * t.sin(), 0.3 * (2.0 * t).sin()]
        })
        .collect()
}

/// Run config.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub points: usize,
    pub eps: f32,
    pub solver: SolverKind,
    pub threads: usize,
    pub max_iter: usize,
    /// Correctness window along the curve (geodesic tolerance).
    pub window: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { points: 128, eps: 0.05, solver: SolverKind::MapUot, threads: 1, max_iter: 400, window: 2 }
    }
}

/// Output: correspondence accuracy + timing.
#[derive(Debug, Clone, Copy)]
pub struct Output {
    pub accuracy: f64,
    pub report: AppReport,
}

/// Run the filter.
pub fn run(cfg: Config) -> Output {
    let total = Timer::start();
    let src = make_shape(cfg.points, 0.15, 21);
    let dst = make_shape(cfg.points, 0.18, 22); // same parameterization, new deformation

    // Balanced Sinkhorn filter over the affinity kernel.
    let problem = Problem::from_point_clouds(&src, &dst, cfg.eps, 1.0);
    let uot = Timer::start();
    let mut session = SolverSession::builder(cfg.solver)
        .threads(cfg.threads)
        .stop(StopRule { tol: 1e-5, delta_tol: 1e-9, max_iter: cfg.max_iter })
        .build(&problem);
    let solve_report = session.solve(&problem).expect("observer-free solve");
    let plan = session.into_plan();
    let uot_s = uot.elapsed().as_secs_f64();

    // Score: argmax along each row vs. identity correspondence, modulo the
    // curve (both shapes share the parameterization).
    let n = cfg.points;
    let mut good = 0usize;
    for i in 0..n {
        let row = plan.row(i);
        let j = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
            .map(|(j, _)| j)
            .expect("non-empty");
        let d = i.abs_diff(j).min(n - i.abs_diff(j)); // circular distance
        if d <= cfg.window {
            good += 1;
        }
    }

    Output {
        accuracy: good as f64 / n as f64,
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: solve_report.iters,
            solver: cfg.solver,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovers_most_correspondences() {
        let out = run(Config::default());
        assert!(out.accuracy > 0.7, "accuracy={}", out.accuracy);
    }

    #[test]
    fn filter_beats_raw_argmax() {
        // Raw kernel argmax (no Sinkhorn) vs filtered: the filter's
        // bistochastic constraint must not hurt, typically helps.
        let cfg = Config { points: 96, ..Default::default() };
        let src = make_shape(cfg.points, 0.15, 21);
        let dst = make_shape(cfg.points, 0.18, 22);
        let problem = Problem::from_point_clouds(&src, &dst, cfg.eps, 1.0);
        let n = cfg.points;
        let raw_acc = {
            let mut good = 0;
            for i in 0..n {
                let row = problem.plan.row(i);
                let j = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).expect("finite"))
                    .map(|(j, _)| j)
                    .expect("non-empty");
                let d = i.abs_diff(j).min(n - i.abs_diff(j));
                if d <= cfg.window {
                    good += 1;
                }
            }
            good as f64 / n as f64
        };
        let out = run(cfg);
        assert!(out.accuracy >= raw_acc * 0.95, "filtered={} raw={raw_acc}", out.accuracy);
    }

    #[test]
    fn shapes_are_closed_curves() {
        let s = make_shape(64, 0.1, 1);
        let d_first_last: f32 = (0..3).map(|c| (s[0][c] - s[63][c]).powi(2)).sum::<f32>().sqrt();
        let d_adjacent: f32 = (0..3).map(|c| (s[0][c] - s[1][c]).powi(2)).sum::<f32>().sqrt();
        assert!(d_first_last < 4.0 * d_adjacent, "curve not closed");
    }
}
