//! The paper's four UOT applications (§2.2, Figs. 2 and 17), on synthetic
//! data (DESIGN.md §Substitutions: histogram/point statistics, not pixel
//! content, drive the solver, so procedural inputs preserve the behaviour).
//!
//! Every app reports a [`AppReport`] splitting end-to-end time into the
//! UOT solve and everything else — the Fig. 2 metric — and can run on any
//! [`SolverKind`], which is how Fig. 17 compares end-to-end speedups.

pub mod bayesian;
pub mod color_transfer;
pub mod domain_adapt;
pub mod entropic2d;
pub mod sinkhorn_filter;
pub mod wmd;

use crate::algo::SolverKind;

/// Timing breakdown of one application run.
#[derive(Debug, Clone, Copy)]
pub struct AppReport {
    /// End-to-end wall time (seconds).
    pub total_s: f64,
    /// Time inside the UOT solver (seconds).
    pub uot_s: f64,
    /// Solver iterations executed.
    pub iters: usize,
    /// Which solver ran.
    pub solver: SolverKind,
}

impl AppReport {
    /// Fraction of end-to-end time spent in UOT (the Fig. 2 y-axis).
    pub fn uot_share(&self) -> f64 {
        if self.total_s <= 0.0 { 0.0 } else { self.uot_s / self.total_s }
    }
}
