//! Sequential cooperative Bayesian inference (paper §2.2; Wang et al.).
//!
//! Cooperative inference iterates Sinkhorn-style normalization of a
//! teacher/learner likelihood matrix until the teaching distribution
//! stabilizes — operationally a balanced UOT solve (fi = 1, uniform
//! marginals). The paper reports 99% of this app's time inside UOT at
//! M=N=1024; the surrounding work is only matrix setup and the final
//! argmax decoding.

use crate::algo::{Problem, SolverKind, SolverSession, StopRule};
use crate::apps::AppReport;
use crate::util::{Matrix, Timer, XorShift};

/// Run config: `hypotheses × data` likelihood matrix.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    pub hypotheses: usize,
    pub data: usize,
    pub solver: SolverKind,
    pub threads: usize,
    pub max_iter: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self {
            hypotheses: 128,
            data: 128,
            solver: SolverKind::MapUot,
            threads: 1,
            max_iter: 500,
            seed: 5,
        }
    }
}

/// Output: the stabilized teaching matrix + consistency metric + timing.
#[derive(Debug)]
pub struct Output {
    pub teaching: Matrix,
    /// Max deviation of the final marginals from uniform (should be ~0).
    pub marginal_err: f32,
    pub report: AppReport,
}

/// Run cooperative inference.
pub fn run(cfg: Config) -> Output {
    let total = Timer::start();
    let mut rng = XorShift::new(cfg.seed);
    // Likelihood matrix: block-diagonal-ish signal + noise, all positive.
    let blocks = 4.max(cfg.hypotheses / 32);
    let plan = Matrix::from_fn(cfg.hypotheses, cfg.data, |i, j| {
        let same = (i * blocks / cfg.hypotheses) == (j * blocks / cfg.data);
        let base = if same { 1.0 } else { 0.15 };
        base * rng.uniform(0.5, 1.5)
    });
    let rpd = vec![1.0 / cfg.hypotheses as f32; cfg.hypotheses];
    let cpd = vec![1.0 / cfg.data as f32; cfg.data];
    let problem = Problem { plan, rpd: rpd.clone(), cpd: cpd.clone(), fi: 1.0 };

    let uot = Timer::start();
    let mut session = SolverSession::builder(cfg.solver)
        .threads(cfg.threads)
        .stop(StopRule { tol: 1e-5, delta_tol: 1e-9, max_iter: cfg.max_iter })
        .build(&problem);
    let solve_report = session.solve(&problem).expect("observer-free solve");
    let teaching = session.into_plan();
    let uot_s = uot.elapsed().as_secs_f64();

    let marginal_err = crate::algo::convergence::marginal_error(&teaching, &rpd, &cpd);
    Output {
        teaching,
        marginal_err,
        report: AppReport {
            total_s: total.elapsed().as_secs_f64(),
            uot_s,
            iters: solve_report.iters,
            solver: cfg.solver,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn teaching_matrix_is_doubly_stochastic_scaled() {
        let out = run(Config { hypotheses: 48, data: 48, ..Default::default() });
        assert!(out.marginal_err < 1e-3, "err={}", out.marginal_err);
    }

    #[test]
    fn uot_dominates_total_time() {
        // The paper's Fig. 2 claim for this app: UOT ~99% of runtime at
        // M=N=1024. At test scale (384² with a tight tolerance) the solve
        // still takes the majority of end-to-end time; the fig02 bench
        // reproduces the full-size share.
        // Threshold is deliberately loose: the unit-test harness runs many
        // tests concurrently, which perturbs wall-clock shares.
        let out = run(Config {
            hypotheses: 384,
            data: 384,
            max_iter: 2000,
            ..Default::default()
        });
        assert!(out.report.uot_share() > 0.35, "share={}", out.report.uot_share());
    }

    #[test]
    fn signal_structure_survives_normalization() {
        let out = run(Config { hypotheses: 32, data: 32, ..Default::default() });
        // Diagonal blocks should still carry above-average mass.
        let mean = 1.0 / (32.0 * 32.0);
        let diag_mean: f32 =
            (0..32).map(|i| out.teaching.get(i, i)).sum::<f32>() / 32.0;
        assert!(diag_mean > mean, "diag={diag_mean} mean={mean}");
    }
}
