//! Minimal property-based-testing harness.
//!
//! `proptest` is not available in the offline crate set, so this module
//! provides the small subset the coordinator/sim invariant tests need:
//! seeded random generation of structured inputs, many-case driving, and
//! greedy input shrinking on failure. Deterministic per seed.

use crate::util::XorShift;

/// Number of cases [`check`] runs by default.
pub const DEFAULT_CASES: usize = 64;

/// A generator of random values driven by the harness RNG.
pub trait Gen {
    type Value;
    fn generate(&self, rng: &mut XorShift) -> Self::Value;
}

impl<T, F: Fn(&mut XorShift) -> T> Gen for F {
    type Value = T;
    fn generate(&self, rng: &mut XorShift) -> T {
        self(rng)
    }
}

/// Uniform integer in `[lo, hi]` (inclusive).
pub fn int_range(lo: usize, hi: usize) -> impl Gen<Value = usize> {
    move |rng: &mut XorShift| lo + rng.below(hi - lo + 1)
}

/// Uniform f32 in `[lo, hi)`.
pub fn f32_range(lo: f32, hi: f32) -> impl Gen<Value = f32> {
    move |rng: &mut XorShift| rng.uniform(lo, hi)
}

/// Vector of `len` draws from `inner`.
pub fn vec_of<G: Gen>(inner: G, len: impl Gen<Value = usize>) -> impl Gen<Value = Vec<G::Value>> {
    move |rng: &mut XorShift| {
        let n = len.generate(rng);
        (0..n).map(|_| inner.generate(rng)).collect()
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult<T> {
    Ok,
    Failed {
        /// Case index that failed first.
        case: usize,
        /// The (possibly shrunk) failing input.
        input: T,
        /// Failure message from the property.
        message: String,
    },
}

/// Run `prop` over `cases` generated inputs. On failure, greedily shrink
/// with `shrink` (returns candidate smaller inputs) before reporting.
pub fn check_with<T: Clone + std::fmt::Debug>(
    seed: u64,
    cases: usize,
    gen: impl Gen<Value = T>,
    shrink: impl Fn(&T) -> Vec<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult<T> {
    let mut rng = XorShift::new(seed);
    for case in 0..cases {
        let input = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink: repeatedly take the first failing candidate.
            let mut cur = input;
            let mut msg = first_msg;
            'outer: loop {
                for cand in shrink(&cur) {
                    if let Err(m) = prop(&cand) {
                        cur = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            return PropResult::Failed { case, input: cur, message: msg };
        }
    }
    PropResult::Ok
}

/// [`check_with`] without shrinking; panics on failure (test-friendly).
pub fn check<T: Clone + std::fmt::Debug>(
    seed: u64,
    gen: impl Gen<Value = T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    match check_with(seed, DEFAULT_CASES, gen, |_| Vec::new(), prop) {
        PropResult::Ok => {}
        PropResult::Failed { case, input, message } => {
            panic!("property failed at case {case} with input {input:?}: {message}")
        }
    }
}

/// Shrinker for `usize`: halves toward `lo`.
pub fn shrink_usize(lo: usize) -> impl Fn(&usize) -> Vec<usize> {
    move |&v: &usize| {
        if v <= lo {
            Vec::new()
        } else {
            vec![lo, lo + (v - lo) / 2, v - 1]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_is_ok() {
        check(1, int_range(0, 100), |&x| {
            if x <= 100 { Ok(()) } else { Err("out of range".into()) }
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        // Property: x < 40. Fails for x >= 40; shrinker should walk down
        // to exactly 40 (the minimal counterexample).
        let r = check_with(
            7,
            256,
            int_range(0, 1000),
            shrink_usize(0),
            |&x| if x < 40 { Ok(()) } else { Err(format!("{x} >= 40")) },
        );
        match r {
            PropResult::Failed { input, .. } => assert_eq!(input, 40),
            PropResult::Ok => panic!("should have failed"),
        }
    }

    #[test]
    fn generators_are_deterministic_per_seed() {
        let mut a = XorShift::new(5);
        let mut b = XorShift::new(5);
        let g = vec_of(f32_range(0.0, 1.0), int_range(1, 8));
        assert_eq!(g.generate(&mut a), g.generate(&mut b));
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_panics_on_failure() {
        check(2, int_range(0, 10), |&x| {
            if x < 5 { Ok(()) } else { Err("too big".into()) }
        });
    }
}
