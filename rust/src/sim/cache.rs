//! Trace-driven set-associative LRU cache hierarchy simulator.
//!
//! Substitutes for the paper's `perf`-counter measurements on the 12900K
//! (Figs. 4, 11, 12): miss *rates* are a function of the access pattern
//! against the cache geometry, which this models exactly — L1 → L2, LRU
//! replacement, write-allocate, 64-byte lines (the 12900K's Golden Cove
//! geometry lives in `config::presets::i9_12900k_caches`).

/// Geometry of one cache level.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub size_bytes: usize,
    pub line_bytes: usize,
    pub assoc: usize,
}

impl CacheConfig {
    pub fn num_sets(&self) -> usize {
        self.size_bytes / (self.line_bytes * self.assoc)
    }
}

/// One set-associative LRU cache level.
#[derive(Debug)]
pub struct Cache {
    pub cfg: CacheConfig,
    /// Per set: tags ordered most-recently-used first.
    sets: Vec<Vec<u64>>,
    pub accesses: u64,
    pub misses: u64,
}

impl Cache {
    pub fn new(cfg: CacheConfig) -> Self {
        assert!(cfg.num_sets().is_power_of_two() || cfg.num_sets() > 0);
        Self { sets: vec![Vec::new(); cfg.num_sets()], cfg, accesses: 0, misses: 0 }
    }

    /// Access one byte address; returns `true` on hit.
    pub fn access(&mut self, addr: u64) -> bool {
        self.accesses += 1;
        let line = addr / self.cfg.line_bytes as u64;
        let set_idx = (line % self.sets.len() as u64) as usize;
        let set = &mut self.sets[set_idx];
        if let Some(pos) = set.iter().position(|&t| t == line) {
            let tag = set.remove(pos);
            set.insert(0, tag); // move to MRU
            true
        } else {
            self.misses += 1;
            if set.len() == self.cfg.assoc {
                set.pop(); // evict LRU
            }
            set.insert(0, line);
            false
        }
    }

    /// Install a line without counting an access (prefetch fill).
    pub fn install(&mut self, addr: u64) {
        let line = addr / self.cfg.line_bytes as u64;
        let set_idx = (line % self.sets.len() as u64) as usize;
        let set = &mut self.sets[set_idx];
        if let Some(pos) = set.iter().position(|&t| t == line) {
            let tag = set.remove(pos);
            set.insert(0, tag);
            return;
        }
        if set.len() == self.cfg.assoc {
            set.pop();
        }
        set.insert(0, line);
    }

    /// Invalidate a line if present (coherence traffic from another core).
    pub fn invalidate(&mut self, addr: u64) {
        let line = addr / self.cfg.line_bytes as u64;
        let set_idx = (line % self.sets.len() as u64) as usize;
        if let Some(pos) = self.sets[set_idx].iter().position(|&t| t == line) {
            self.sets[set_idx].remove(pos);
        }
    }

    pub fn miss_rate(&self) -> f64 {
        if self.accesses == 0 { 0.0 } else { self.misses as f64 / self.accesses as f64 }
    }
}

/// Two-level hierarchy configuration.
#[derive(Debug, Clone, Copy)]
pub struct HierarchyConfig {
    pub l1: CacheConfig,
    pub l2: CacheConfig,
    /// Miss-triggered L2 stream-prefetch degree (lines fetched ahead on an
    /// L2 miss; 0 disables). Models the L2 streamer that makes measured L2
    /// miss rates on sequential sweeps single-digit (paper Fig. 4: 4.6%).
    pub l2_prefetch: usize,
}

/// L1 → L2 hierarchy; L2 sees only L1 misses (paper's perf counters count
/// L2 miss rate as L2-misses / L2-accesses the same way).
#[derive(Debug)]
pub struct Hierarchy {
    pub l1: Cache,
    pub l2: Cache,
    prefetch: usize,
}

impl Hierarchy {
    pub fn new(cfg: HierarchyConfig) -> Self {
        Self { l1: Cache::new(cfg.l1), l2: Cache::new(cfg.l2), prefetch: cfg.l2_prefetch }
    }

    /// Access one address through the hierarchy.
    #[inline]
    pub fn access(&mut self, addr: u64) {
        if !self.l1.access(addr) {
            if !self.l2.access(addr) && self.prefetch > 0 {
                // Miss-triggered streamer: pull the next lines into L2.
                let line_bytes = self.l2.cfg.line_bytes as u64;
                for k in 1..=self.prefetch as u64 {
                    self.l2.install(addr + k * line_bytes);
                }
            }
        }
    }

    pub fn stats(&self) -> HierarchyStats {
        HierarchyStats {
            l1_accesses: self.l1.accesses,
            l1_misses: self.l1.misses,
            l2_accesses: self.l2.accesses,
            l2_misses: self.l2.misses,
        }
    }
}

/// Aggregated statistics from a simulation run.
#[derive(Debug, Clone, Copy, Default)]
pub struct HierarchyStats {
    pub l1_accesses: u64,
    pub l1_misses: u64,
    pub l2_accesses: u64,
    pub l2_misses: u64,
}

impl HierarchyStats {
    pub fn l1_miss_rate(&self) -> f64 {
        if self.l1_accesses == 0 { 0.0 } else { self.l1_misses as f64 / self.l1_accesses as f64 }
    }

    pub fn l2_miss_rate(&self) -> f64 {
        if self.l2_accesses == 0 { 0.0 } else { self.l2_misses as f64 / self.l2_accesses as f64 }
    }

    pub fn merge(&mut self, other: &HierarchyStats) {
        self.l1_accesses += other.l1_accesses;
        self.l1_misses += other.l1_misses;
        self.l2_accesses += other.l2_accesses;
        self.l2_misses += other.l2_misses;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> CacheConfig {
        CacheConfig { size_bytes: 256, line_bytes: 64, assoc: 2 } // 2 sets
    }

    #[test]
    fn repeated_access_hits() {
        let mut c = Cache::new(tiny());
        assert!(!c.access(0));
        for _ in 0..10 {
            assert!(c.access(4)); // same line as 0
        }
        assert_eq!(c.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = Cache::new(tiny());
        // set 0 holds lines with (line % 2 == 0): addresses 0, 128, 256...
        c.access(0); // line 0 -> set 0
        c.access(128); // line 2 -> set 0 (full now)
        c.access(0); // touch line 0 (MRU)
        c.access(256); // line 4 -> evicts LRU = line 2
        assert!(c.access(0), "line 0 should survive");
        assert!(!c.access(128), "line 2 was evicted");
    }

    #[test]
    fn streaming_miss_rate_is_one_per_line() {
        // Stream 64 KiB of f32s: every 16th access misses (64B line / 4B).
        let mut c = Cache::new(CacheConfig { size_bytes: 4096, line_bytes: 64, assoc: 4 });
        for i in 0..16_384u64 {
            c.access(i * 4);
        }
        let rate = c.miss_rate();
        assert!((rate - 1.0 / 16.0).abs() < 1e-3, "rate={rate}");
    }

    #[test]
    fn strided_columns_miss_every_access() {
        // Column sweep of a 1024x1024 f32 matrix: stride 4096B >> cache.
        let mut c = Cache::new(CacheConfig { size_bytes: 32 * 1024, line_bytes: 64, assoc: 8 });
        for j in 0..4u64 {
            for i in 0..1024u64 {
                c.access(i * 4096 + j * 4);
            }
        }
        // First column: all miss. Next columns: same lines already evicted
        // (1024 lines > 512 cache lines) -> all miss again.
        assert!(c.miss_rate() > 0.99, "rate={}", c.miss_rate());
    }

    #[test]
    fn working_set_that_fits_has_only_compulsory_misses() {
        let mut c = Cache::new(CacheConfig { size_bytes: 64 * 1024, line_bytes: 64, assoc: 8 });
        for _round in 0..4 {
            for i in 0..4096u64 {
                c.access(i * 4); // 16 KiB working set
            }
        }
        assert_eq!(c.misses, 4096 / 16); // only the first round misses
    }

    #[test]
    fn invalidate_forces_remiss() {
        let mut c = Cache::new(tiny());
        c.access(0);
        assert!(c.access(0));
        c.invalidate(0);
        assert!(!c.access(0));
    }

    #[test]
    fn hierarchy_l2_sees_only_l1_misses() {
        let cfg = HierarchyConfig {
            l1: CacheConfig { size_bytes: 1024, line_bytes: 64, assoc: 2 },
            l2: CacheConfig { size_bytes: 8192, line_bytes: 64, assoc: 4 },
            l2_prefetch: 0,
        };
        let mut h = Hierarchy::new(cfg);
        for i in 0..256u64 {
            h.access(i * 4); // 1 KiB stream: 16 lines
        }
        let s = h.stats();
        assert_eq!(s.l1_accesses, 256);
        assert_eq!(s.l2_accesses, s.l1_misses);
    }

    #[test]
    fn l2_streamer_converts_stream_misses_to_hits() {
        let mk = |pf: usize| HierarchyConfig {
            l1: CacheConfig { size_bytes: 1024, line_bytes: 64, assoc: 2 },
            l2: CacheConfig { size_bytes: 64 * 1024, line_bytes: 64, assoc: 8 },
            l2_prefetch: pf,
        };
        let run = |pf: usize| {
            let mut h = Hierarchy::new(mk(pf));
            for i in 0..65_536u64 {
                h.access(i * 4); // 256 KiB stream
            }
            h.stats().l2_miss_rate()
        };
        let none = run(0);
        let deg16 = run(16);
        assert!(none > 0.95, "no-prefetch stream should miss L2: {none}");
        // Miss-triggered degree-16 streamer: ~1 miss per 17 lines.
        assert!((deg16 - 1.0 / 17.0).abs() < 0.02, "deg16={deg16}");
    }
}
