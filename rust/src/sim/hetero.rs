//! Heterogeneous P/E-core scheduling model (paper §6 future work: "taking
//! advantage of complex structure of 12900K's performance cores and
//! efficient cores").
//!
//! The 12900K has 8 Golden Cove P-cores and 8 Gracemont E-cores with very
//! different sustained per-core bandwidth/compute. MAP-UOT's row partition
//! (`parallel.rs` splits rows evenly) is optimal for homogeneous cores but
//! leaves P-cores idle waiting for E-cores on a hybrid part. This module
//! models one iteration under three schedules and quantifies the §6
//! opportunity:
//!
//! * `Uniform`      — even rows per core (the paper's Algorithm 1)
//! * `Proportional` — rows ∝ per-core throughput (static, oracle weights)
//! * `WorkStealing` — chunked deque, cores pull; approaches proportional
//!   with bounded chunk overhead
//!
//! All schedules share the DRAM-bandwidth ceiling: per-core rates are
//! clipped so the aggregate never exceeds the socket's peak (the same
//! saturation law as `sim::multicore`).

use crate::algo::SolverKind;

/// A hybrid CPU: two core classes with per-core sustained solver
/// throughput (giga-element-accesses/s) and a socket bandwidth ceiling.
#[derive(Debug, Clone, Copy)]
pub struct HybridCpu {
    pub p_cores: usize,
    pub e_cores: usize,
    /// Per-P-core throughput for a memory-bound sweep (Gelem/s).
    pub p_rate: f64,
    /// Per-E-core throughput (Gracemont: narrower, lower clock).
    pub e_rate: f64,
    /// Socket DRAM ceiling in Gelem/s (f32: 76.8 GB/s → 19.2 Gelem/s).
    pub socket_ceiling: f64,
}

/// 12900K preset: E-cores sustain ~45% of a P-core on streaming loops.
pub fn i9_12900k_hybrid() -> HybridCpu {
    HybridCpu { p_cores: 8, e_cores: 8, p_rate: 2.7, e_rate: 1.2, socket_ceiling: 19.2 }
}

/// Scheduling policy for the row partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    Uniform,
    Proportional,
    /// Work stealing with this many chunks per core.
    WorkStealing { chunks_per_core: usize },
}

/// Effective per-core rates after the socket ceiling is applied
/// proportionally (bandwidth is shared, not reserved).
fn clipped_rates(cpu: &HybridCpu) -> (f64, f64) {
    let raw = cpu.p_cores as f64 * cpu.p_rate + cpu.e_cores as f64 * cpu.e_rate;
    let scale = (cpu.socket_ceiling / raw).min(1.0);
    (cpu.p_rate * scale, cpu.e_rate * scale)
}

/// Predicted seconds for one iteration of `kind` over `m × n` under a
/// schedule. Work per row is `accesses_per_element · n` element accesses.
pub fn iter_time_s(
    cpu: &HybridCpu,
    kind: SolverKind,
    m: usize,
    n: usize,
    schedule: Schedule,
) -> f64 {
    let (p, e) = clipped_rates(cpu);
    let row_work = kind.accesses_per_element() as f64 * n as f64; // accesses/row
    let total_rows = m as f64;
    match schedule {
        Schedule::Uniform => {
            // Even split: the slowest populated class finishes last.
            let cores = (cpu.p_cores + cpu.e_cores) as f64;
            let rows_per_core = total_rows / cores;
            let t_p = if cpu.p_cores > 0 { rows_per_core * row_work / (p * 1e9) } else { 0.0 };
            let t_e = if cpu.e_cores > 0 { rows_per_core * row_work / (e * 1e9) } else { 0.0 };
            t_p.max(t_e)
        }
        Schedule::Proportional => {
            // Rows ∝ rate ⇒ all cores finish together.
            let agg = cpu.p_cores as f64 * p + cpu.e_cores as f64 * e;
            total_rows * row_work / (agg * 1e9)
        }
        Schedule::WorkStealing { chunks_per_core } => {
            // Proportional finish plus one trailing chunk of the slowest
            // class plus per-chunk deque overhead (~80 ns CAS + cache line).
            let agg = cpu.p_cores as f64 * p + cpu.e_cores as f64 * e;
            let ideal = total_rows * row_work / (agg * 1e9);
            let chunks = (cpu.p_cores + cpu.e_cores) * chunks_per_core.max(1);
            let chunk_rows = total_rows / chunks as f64;
            let tail = chunk_rows * row_work / (e * 1e9);
            let overhead = chunks as f64 * 80e-9 / (cpu.p_cores + cpu.e_cores) as f64;
            ideal + tail + overhead
        }
    }
}

/// Speedup of a schedule over `Uniform` (the §6 headroom number).
pub fn speedup_vs_uniform(
    cpu: &HybridCpu,
    kind: SolverKind,
    m: usize,
    n: usize,
    schedule: Schedule,
) -> f64 {
    iter_time_s(cpu, kind, m, n, Schedule::Uniform) / iter_time_s(cpu, kind, m, n, schedule)
}

#[cfg(test)]
mod tests {
    use super::*;

    const S: usize = 4096;

    #[test]
    fn uniform_is_bound_by_e_cores() {
        let cpu = i9_12900k_hybrid();
        let (p, e) = clipped_rates(&cpu);
        assert!(p > e);
        let t_uni = iter_time_s(&cpu, SolverKind::MapUot, S, S, Schedule::Uniform);
        // Uniform time equals the E-core time for its share.
        let rows_per_core = S as f64 / 16.0;
        let expect = rows_per_core * 2.0 * S as f64 / (e * 1e9);
        assert!((t_uni - expect).abs() < 1e-9);
    }

    #[test]
    fn proportional_beats_uniform_by_the_rate_gap() {
        let cpu = i9_12900k_hybrid();
        let s = speedup_vs_uniform(&cpu, SolverKind::MapUot, S, S, Schedule::Proportional);
        // Analytic: uniform is bound by 16·e; proportional achieves
        // 8p + 8e. Gain = (8p+8e)/(16e) = (p/e + 1)/2 ≈ 1.63 for the preset.
        let (p, e) = clipped_rates(&cpu);
        let expect = (p / e + 1.0) / 2.0;
        assert!((s - expect).abs() < 1e-6, "s={s} expect={expect}");
        assert!(s > 1.3 && s < 2.0);
    }

    #[test]
    fn work_stealing_approaches_proportional_with_more_chunks() {
        let cpu = i9_12900k_hybrid();
        let prop = iter_time_s(&cpu, SolverKind::MapUot, S, S, Schedule::Proportional);
        let ws4 = iter_time_s(&cpu, SolverKind::MapUot, S, S, Schedule::WorkStealing { chunks_per_core: 4 });
        let ws32 = iter_time_s(&cpu, SolverKind::MapUot, S, S, Schedule::WorkStealing { chunks_per_core: 32 });
        assert!(ws32 < ws4, "more chunks should tighten the tail");
        assert!(ws32 >= prop, "stealing cannot beat the oracle split");
        assert!((ws32 - prop) / prop < 0.08, "32 chunks within 8% of oracle");
    }

    #[test]
    fn ceiling_binds_for_memory_bound_kinds() {
        let cpu = i9_12900k_hybrid();
        // Raw aggregate 8·2.7 + 8·1.2 = 31.2 > 19.2 ceiling: clipped.
        let (p, e) = clipped_rates(&cpu);
        let agg = 8.0 * p + 8.0 * e;
        assert!((agg - cpu.socket_ceiling).abs() < 1e-9, "agg={agg}");
    }

    #[test]
    fn homogeneous_cpu_has_no_headroom() {
        let cpu = HybridCpu { p_cores: 16, e_cores: 0, p_rate: 2.0, e_rate: 1.0, socket_ceiling: 19.2 };
        let s = speedup_vs_uniform(&cpu, SolverKind::MapUot, S, S, Schedule::Proportional);
        assert!((s - 1.0).abs() < 1e-9);
    }
}
