//! Simulators substituting for hardware the paper used but this testbed
//! lacks (see DESIGN.md §Substitutions):
//!
//! * [`cache`] + [`memtrace`] — 12900K perf counters (Figs. 4, 11, 12)
//! * [`gpu`] — RTX 3090 Ti + Nsight Compute (Figs. 5, 8, 13, 14, 15)
//! * [`cluster`] — Tianhe-1 + MPI (Fig. 16)
//! * [`roofline`] — the §3.1 Roofline analysis (Fig. 3, Eq. 1)

pub mod cache;
pub mod cluster;
pub mod gpu;
pub mod hetero;
pub mod memtrace;
pub mod multicore;
pub mod roofline;
