//! Exact per-algorithm memory-access traces, fed to the cache simulator.
//!
//! Each function replays the load/store stream of one solver iteration at
//! byte-address granularity, with every buffer placed at a realistic
//! 64-byte-aligned base address. The traces count matrix loads and stores
//! separately (an `A[i][j] *= f` is one load and one store, like the
//! paper's §3.1 operation counting) and include the factor/accumulator
//! vector traffic, so simulated miss rates are comparable with the paper's
//! `perf`-measured ones (Figs. 4, 11, 12).

use crate::algo::SolverKind;
use crate::sim::cache::{Cache, CacheConfig, Hierarchy, HierarchyConfig, HierarchyStats};

const F: u64 = 4; // sizeof(f32)

/// Base addresses for the buffers of one solve (64-byte aligned, disjoint).
#[derive(Debug, Clone, Copy)]
pub struct Layout {
    pub a: u64,
    pub rpd: u64,
    pub cpd: u64,
    pub fcol: u64,
    pub rowsum: u64,
    pub ncs: u64,
}

impl Layout {
    pub fn new(m: usize, n: usize) -> Self {
        let align = |x: u64| (x + 63) & !63;
        let a = 0x10000;
        let rpd = align(a + (m * n) as u64 * F);
        let cpd = align(rpd + m as u64 * F);
        let fcol = align(cpd + n as u64 * F);
        let rowsum = align(fcol + n as u64 * F);
        let ncs = align(rowsum + m as u64 * F);
        Self { a, rpd, cpd, fcol, rowsum, ncs }
    }

    #[inline]
    fn aij(&self, i: usize, j: usize, n: usize) -> u64 {
        self.a + (i * n + j) as u64 * F
    }
}

/// Replay one POT (NumPy 4-sweep) iteration.
pub fn trace_pot(h: &mut Hierarchy, m: usize, n: usize) {
    let l = Layout::new(m, n);
    // Sweep 1: colsum = A.sum(0) — load A row-major, r/w sums vector.
    for i in 0..m {
        for j in 0..n {
            h.access(l.aij(i, j, n));
            h.access(l.fcol + j as u64 * F); // accumulate into sums (reuse fcol buf)
        }
    }
    // Sweep 2: A *= fcol — load fcol[j], load+store A.
    for i in 0..m {
        for j in 0..n {
            h.access(l.fcol + j as u64 * F);
            h.access(l.aij(i, j, n));
            h.access(l.aij(i, j, n));
        }
    }
    // Sweep 3: rowsum = A.sum(1).
    for i in 0..m {
        for j in 0..n {
            h.access(l.aij(i, j, n));
        }
        h.access(l.rowsum + i as u64 * F);
    }
    // Sweep 4: A *= frow.
    for i in 0..m {
        h.access(l.rowsum + i as u64 * F);
        for j in 0..n {
            h.access(l.aij(i, j, n));
            h.access(l.aij(i, j, n));
        }
    }
}

/// Replay one COFFEE (phase-fused 2-sweep) iteration.
pub fn trace_coffee(h: &mut Hierarchy, m: usize, n: usize) {
    let l = Layout::new(m, n);
    // Phase A: col-rescale + row-sum.
    for i in 0..m {
        for j in 0..n {
            h.access(l.fcol + j as u64 * F);
            h.access(l.aij(i, j, n)); // load
            h.access(l.aij(i, j, n)); // store
        }
        h.access(l.rowsum + i as u64 * F);
    }
    // Phase B: row-rescale + next colsum.
    for i in 0..m {
        h.access(l.rowsum + i as u64 * F);
        for j in 0..n {
            h.access(l.aij(i, j, n)); // load
            h.access(l.aij(i, j, n)); // store
            h.access(l.ncs + j as u64 * F); // load
            h.access(l.ncs + j as u64 * F); // store
        }
    }
}

/// Replay one MAP-UOT (fused double-loop) iteration — Algorithm 1.
pub fn trace_mapuot(h: &mut Hierarchy, m: usize, n: usize) {
    let l = Layout::new(m, n);
    for i in 0..m {
        // Inner loop 1: A[i][j] *= Factor_col[j]; Sum_row += A[i][j].
        for j in 0..n {
            h.access(l.fcol + j as u64 * F);
            h.access(l.aij(i, j, n)); // load
            h.access(l.aij(i, j, n)); // store (Sum_row is a register)
        }
        // Inner loop 2: A[i][j] *= fr; NextSum_col[j] += A[i][j].
        // The row was just written: it re-hits L1 if it fits (the paper's
        // "as long as the cache can accommodate the row" condition).
        for j in 0..n {
            h.access(l.aij(i, j, n)); // load
            h.access(l.aij(i, j, n)); // store
            h.access(l.ncs + j as u64 * F); // load
            h.access(l.ncs + j as u64 * F); // store
        }
        h.access(l.rpd + i as u64 * F);
    }
}

/// The paper's Fig. 1 *C demo* column rescaling (j outer, i inner): the
/// stride-N pattern §3.1 blames for baseline cache-unfriendliness.
pub fn trace_strided_column_rescale(h: &mut Hierarchy, m: usize, n: usize) {
    let l = Layout::new(m, n);
    for j in 0..n {
        h.access(l.fcol + j as u64 * F);
        for i in 0..m {
            h.access(l.aij(i, j, n));
            h.access(l.aij(i, j, n));
        }
    }
}

/// Simulate `iters` iterations of `kind` and return hierarchy stats.
pub fn simulate(
    cfg: HierarchyConfig,
    kind: SolverKind,
    m: usize,
    n: usize,
    iters: usize,
) -> HierarchyStats {
    let mut h = Hierarchy::new(cfg);
    for _ in 0..iters {
        match kind {
            SolverKind::Pot => trace_pot(&mut h, m, n),
            SolverKind::Coffee => trace_coffee(&mut h, m, n),
            SolverKind::MapUot => trace_mapuot(&mut h, m, n),
        }
    }
    h.stats()
}

/// Multi-threaded MAP-UOT L1 model for the false-sharing figure (Fig. 12).
///
/// Each thread owns a private L1 (per-core on the 12900K) and streams its
/// contiguous row block. `padded_accumulators` selects the paper's design
/// (each thread's `NextSum_col` separately allocated / 64-byte aligned) vs.
/// a naive contiguous `NextSum_col[T][N]` whose boundary lines are shared
/// between adjacent threads, causing invalidation ping-pong.
pub fn simulate_mapuot_threads(
    l1: CacheConfig,
    m: usize,
    n: usize,
    threads: usize,
    padded_accumulators: bool,
) -> HierarchyStats {
    let t = threads.max(1).min(m);
    let rows_per = m.div_ceil(t);
    let l = Layout::new(m, n);
    let mut agg = HierarchyStats::default();

    // Accumulator row stride in bytes: padded -> rounded to full lines
    // (no line crosses a thread boundary); naive -> exactly N floats.
    let acc_stride = if padded_accumulators {
        (n as u64 * F + 63) & !63
    } else {
        n as u64 * F
    };
    let acc_base = l.ncs;

    for tid in 0..t {
        let mut c = Cache::new(l1);
        let row_lo = tid * rows_per;
        let row_hi = ((tid + 1) * rows_per).min(m);
        let my_acc = acc_base + tid as u64 * acc_stride;

        // A line of this thread's accumulator is "shared" when some byte of
        // it belongs to a neighbour's accumulator row. Every write to a
        // shared line costs a coherence miss (invalidate + refetch): model
        // it as an invalidation right before the access.
        let shared_line = |addr: u64| -> bool {
            if padded_accumulators {
                return false;
            }
            let line_lo = addr & !63;
            let line_hi = line_lo + 63;
            line_lo < my_acc || line_hi >= my_acc + n as u64 * F
        };

        for i in row_lo..row_hi {
            for j in 0..n {
                c.access(l.fcol + j as u64 * F);
                c.access(l.aij(i, j, n));
                c.access(l.aij(i, j, n));
            }
            for j in 0..n {
                c.access(l.aij(i, j, n));
                c.access(l.aij(i, j, n));
                let acc_addr = my_acc + j as u64 * F;
                if shared_line(acc_addr) {
                    // Neighbour wrote the line since we last held it.
                    c.invalidate(acc_addr);
                }
                c.access(acc_addr);
                c.access(acc_addr);
            }
            c.access(l.rpd + i as u64 * F);
        }
        agg.merge(&HierarchyStats {
            l1_accesses: c.accesses,
            l1_misses: c.misses,
            l2_accesses: 0,
            l2_misses: 0,
        });
    }
    agg
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::i9_12900k_caches;

    #[test]
    fn layout_buffers_disjoint_and_aligned() {
        let l = Layout::new(100, 50);
        let bases = [l.a, l.rpd, l.cpd, l.fcol, l.rowsum, l.ncs];
        for w in bases.windows(2) {
            assert!(w[0] < w[1]);
        }
        for b in &bases[1..] {
            assert_eq!(b % 64, 0);
        }
    }

    #[test]
    fn mapuot_misses_fewer_than_coffee_fewer_than_pot() {
        let cfg = i9_12900k_caches();
        let (m, n) = (256, 256);
        let pot = simulate(cfg, SolverKind::Pot, m, n, 2);
        let coffee = simulate(cfg, SolverKind::Coffee, m, n, 2);
        let map = simulate(cfg, SolverKind::MapUot, m, n, 2);
        assert!(map.l1_misses < coffee.l1_misses, "{map:?} vs {coffee:?}");
        assert!(coffee.l1_misses < pot.l1_misses, "{coffee:?} vs {pot:?}");
        // Miss *rate* ordering holds too (the paper's Fig. 11 metric).
        assert!(map.l1_miss_rate() < pot.l1_miss_rate());
    }

    #[test]
    fn mapuot_row_reuse_hits_when_row_fits_l1() {
        // 128 cols = 512 B per row: second inner loop must hit.
        let cfg = i9_12900k_caches();
        let s = simulate(cfg, SolverKind::MapUot, 64, 128, 1);
        // Compulsory misses ~ matrix lines (64*128*4/64 = 512) + vectors.
        assert!(s.l1_misses < 600, "{s:?}");
    }

    #[test]
    fn strided_rescale_misses_dominate() {
        let cfg = i9_12900k_caches();
        let mut h_row = Hierarchy::new(cfg);
        let mut h_col = Hierarchy::new(cfg);
        // 1024x1024: column stride 4 KiB defeats a 48 KiB L1.
        trace_coffee(&mut h_row, 512, 1024);
        trace_strided_column_rescale(&mut h_col, 512, 1024);
        assert!(h_col.stats().l1_miss_rate() > 3.0 * h_row.stats().l1_miss_rate());
    }

    #[test]
    fn padded_threads_have_flat_miss_rate() {
        let l1 = i9_12900k_caches().l1;
        // Large enough that per-thread cold-start vector misses amortize.
        let (m, n) = (512, 256);
        let r1 = simulate_mapuot_threads(l1, m, n, 1, true).l1_miss_rate();
        let r16 = simulate_mapuot_threads(l1, m, n, 16, true).l1_miss_rate();
        assert!((r1 - r16).abs() / r1 < 0.15, "r1={r1} r16={r16}");
    }

    #[test]
    fn unpadded_narrow_matrix_shows_false_sharing() {
        let l1 = i9_12900k_caches().l1;
        // N = 8 cols -> accumulator rows are 32 B: every line shared.
        let padded = simulate_mapuot_threads(l1, 64, 8, 8, true);
        let naive = simulate_mapuot_threads(l1, 64, 8, 8, false);
        assert!(
            naive.l1_misses > 2 * padded.l1_misses,
            "naive={naive:?} padded={padded:?}"
        );
    }
}
