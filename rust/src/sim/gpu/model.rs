//! Kernel timing, throughput and memory models (Figs. 5, 8, 13, 14, 15).

use super::tiling::{concurrent_blocks, occupancy, TileConfig};
use super::GpuConfig;

const F: f64 = 4.0; // sizeof(f32)
const MB: f64 = 1024.0 * 1024.0;

/// Which fused kernel of the GPU design (paper §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Part {
    /// Part ② (Algorithm 2): row rescaling + column-sum accumulation.
    Part2,
    /// Part ④ (Algorithm 3): column rescaling + row-sum reduction.
    Part4,
}

/// Effective streaming efficiency of a fully-coalesced fused kernel.
const MAPUOT_STREAM_EFF: f64 = 0.89;
/// Effective streaming efficiency of the CuPy baseline's generic kernels.
const POT_STREAM_EFF: f64 = 0.80;
/// Host-side Python/CuPy dispatch overhead per baseline iteration (ms):
/// seven-ish kernel launches, descriptor setup, host sync. Calibrated so
/// the small-size end of Fig. 13 peaks at ~3.5× (the paper's max).
const POT_HOST_OVERHEAD_MS: f64 = 0.05;
/// Fixed latency of one block-row step (reduce + atomic + sync), ns.
const BLOCK_ROW_LATENCY_NS: f64 = 1600.0;
/// Mild penalty per Ny doubling past 8 (register pressure / smem growth —
/// calibrated so the Fig. 8 optimum lands at Ny = 8 as measured).
const NY_PRESSURE: f64 = 0.012;

/// Latency-hiding factor from per-thread unrolling: deeper `Ny` loops keep
/// more loads in flight (paper §4.2.2 "help hide memory access latency").
fn hiding(ny: usize) -> f64 {
    (ny as f64).sqrt().min(4.0)
}

/// Time (ms) of one fused-kernel launch over an `m × n` matrix.
pub fn kernel_time_ms(cfg: &GpuConfig, part: Part, tile: TileConfig, m: usize, n: usize) -> f64 {
    let (m, n) = (m as f64, n as f64);
    // Streaming term: one read + one write of the matrix.
    let bytes = 2.0 * m * n * F;
    let occ = occupancy(cfg, tile);
    // Bandwidth saturates once enough warps are resident; below ~2/3
    // occupancy the achieved bandwidth degrades roughly linearly.
    let bw_util = MAPUOT_STREAM_EFF * (occ / 0.66).min(1.0);
    let t_stream = bytes / (cfg.peak_bw_gbs * 1e9 * bw_util) * 1e3;

    // Latency term: every (block, row-step) pays a fixed reduce/atomic/sync
    // latency; concurrent blocks and per-thread unrolling hide it.
    let block_rows = match part {
        // Part ②: grid (N/Tx, M/(Ty·Ny)); each block does Ny row-steps.
        Part::Part2 => (n / tile.tx as f64) * (m / tile.ty as f64),
        // Part ④: 1-D blocks of Tx threads; N/Tx blocks cover each row.
        Part::Part4 => (n / tile.tx as f64) * m,
    };
    let conc = concurrent_blocks(cfg, tile) as f64 * hiding(tile.ny);
    let t_lat = block_rows * BLOCK_ROW_LATENCY_NS / conc * 1e-6;

    // Atomic serialization: longest chain of conflicting atomicAdds.
    let chain = match part {
        Part::Part2 => m / (tile.ty as f64 * tile.ny as f64), // per Sum_col[j]
        Part::Part4 => n / tile.tx as f64,                    // per Sum_row[i]
    };
    let t_atomic = chain * cfg.atomic_conflict_ns * 1e-6;

    let pressure = if tile.ny > 8 { 1.0 + NY_PRESSURE * (tile.ny as f64 / 8.0 - 1.0) } else { 1.0 };
    (t_stream.max(t_lat) + t_atomic) * pressure + cfg.kernel_launch_us * 1e-3
}

/// One MAP-UOT GPU iteration (ms): part ② + part ④ + the O(N) factor
/// kernels (folded into launch overhead).
pub fn mapuot_iter_ms(cfg: &GpuConfig, m: usize, n: usize, t2: TileConfig, t4: TileConfig) -> f64 {
    kernel_time_ms(cfg, Part::Part2, t2, m, n)
        + kernel_time_ms(cfg, Part::Part4, t4, m, n)
        + 2.0 * cfg.kernel_launch_us * 1e-3 // factor/zero kernels
}

/// One POT (CuPy) GPU iteration (ms): four generic streaming kernels
/// (6·M·N traffic) + the Python/CuPy dispatch overhead.
pub fn pot_iter_ms(cfg: &GpuConfig, m: usize, n: usize) -> f64 {
    let bytes = 6.0 * m as f64 * n as f64 * F;
    let t_stream = bytes / (cfg.peak_bw_gbs * 1e9 * POT_STREAM_EFF) * 1e3;
    t_stream + POT_HOST_OVERHEAD_MS + 4.0 * cfg.kernel_launch_us * 1e-3
}

/// Achieved global load/store throughput (GB/s) over one iteration —
/// the Fig. 5 / Fig. 14 metric (bytes moved / wall time).
///
/// Reproduction note (EXPERIMENTS.md): under consistent wall-time byte
/// accounting, MAP-UOT's *store* throughput and *total* bandwidth
/// utilization rise (as in the paper), while its *load* byte count is cut
/// in half by the fusion itself — so a wall-time load-throughput increment
/// like the paper's Ncu +22.7% is not reconstructible from a consistent
/// timing model; we report the direction via `total_gbs` instead.
#[derive(Debug, Clone, Copy)]
pub struct Throughput {
    pub load_gbs: f64,
    pub store_gbs: f64,
}

impl Throughput {
    /// Total achieved bandwidth (bandwidth utilization — always higher for
    /// the fused kernels).
    pub fn total_gbs(&self) -> f64 {
        self.load_gbs + self.store_gbs
    }
}

/// Throughput for a solver kind: `fused = true` models MAP-UOT (loads =
/// stores = M·N elements per pass over its two kernels), `false` the CuPy
/// baseline (4·M·N loads, 2·M·N stores over four kernels).
pub fn throughput_gbs(cfg: &GpuConfig, m: usize, n: usize, fused: bool) -> Throughput {
    let mn = m as f64 * n as f64;
    if fused {
        let t = mapuot_iter_ms(cfg, m, n, TileConfig::part2_default(), TileConfig::part4_default());
        Throughput {
            load_gbs: 2.0 * mn * F / (t * 1e-3) / 1e9,
            store_gbs: 2.0 * mn * F / (t * 1e-3) / 1e9,
        }
    } else {
        let t = pot_iter_ms(cfg, m, n);
        Throughput {
            load_gbs: 4.0 * mn * F / (t * 1e-3) / 1e9,
            store_gbs: 2.0 * mn * F / (t * 1e-3) / 1e9,
        }
    }
}

/// Peak device memory (MB) during a solve — Fig. 15.
///
/// Model (DESIGN.md §Substitutions): both hold the framework context plus
/// buffers proportional to the plan. The CuPy baseline materializes the
/// plan plus broadcast temporaries and reduction workspaces (≈ 4.4 plan
/// sizes, calibrated on the paper's 4096² point: 413 MB); MAP-UOT holds
/// the plan, its double buffer and one workspace (3 plan sizes → 323 MB).
pub fn peak_memory_mb(cfg: &GpuConfig, m: usize, n: usize, fused: bool) -> f64 {
    let plan_mb = m as f64 * n as f64 * F / MB;
    let factor = if fused { 3.0 } else { 4.4 };
    cfg.context_mb + factor * plan_mb + (m + n) as f64 * F * 6.0 / MB
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::rtx_3090ti_gpu;

    #[test]
    fn fig8_optimum_part2_is_tx32_ny8() {
        let g = rtx_3090ti_gpu();
        let mut best = (f64::MAX, TileConfig { tx: 0, ty: 2, ny: 0 });
        for tx in [32, 64, 128, 256, 512] {
            for ny in [1, 2, 4, 8, 16] {
                let t = kernel_time_ms(&g, Part::Part2, TileConfig { tx, ty: 2, ny }, 10240, 10240);
                if t < best.0 {
                    best = (t, TileConfig { tx, ty: 2, ny });
                }
            }
        }
        assert_eq!(best.1.ny, 8, "best={:?}", best);
    }

    #[test]
    fn fig8_part4_tx32_is_catastrophic() {
        let g = rtx_3090ti_gpu();
        let t32 = kernel_time_ms(&g, Part::Part4, TileConfig { tx: 32, ty: 1, ny: 1 }, 10240, 10240);
        let t128 = kernel_time_ms(&g, Part::Part4, TileConfig { tx: 128, ty: 1, ny: 8 }, 10240, 10240);
        assert!(t32 > 2.5 * t128, "t32={t32} t128={t128}");
        // and the best configuration approaches the streaming floor (~0.93 ms)
        assert!(t128 < 1.3, "t128={t128}");
        assert!(t128 > 0.8, "t128={t128}");
    }

    #[test]
    fn fig13_mapuot_beats_pot_at_all_sizes() {
        let g = rtx_3090ti_gpu();
        let (t2, t4) = (TileConfig::part2_default(), TileConfig::part4_default());
        for s in [512usize, 1024, 2048, 4096, 10240] {
            let pot = pot_iter_ms(&g, s, s);
            let map = mapuot_iter_ms(&g, s, s, t2, t4);
            assert!(pot > map, "size={s}: pot={pot} map={map}");
        }
    }

    #[test]
    fn fig13_speedup_larger_at_small_sizes() {
        let g = rtx_3090ti_gpu();
        let (t2, t4) = (TileConfig::part2_default(), TileConfig::part4_default());
        let sp = |s: usize| pot_iter_ms(&g, s, s) / mapuot_iter_ms(&g, s, s, t2, t4);
        assert!(sp(512) > sp(4096), "sp512={} sp4096={}", sp(512), sp(4096));
        assert!(sp(4096) > 1.3 && sp(4096) < 2.5, "sp4096={}", sp(4096));
        assert!(sp(512) < 5.0, "sp512={}", sp(512));
    }

    #[test]
    fn fig14_throughput_increments_positive() {
        let g = rtx_3090ti_gpu();
        for s in [1024usize, 4096, 10240] {
            let base = throughput_gbs(&g, s, s, false);
            let fused = throughput_gbs(&g, s, s, true);
            // Store throughput and total bandwidth utilization both rise
            // (see Throughput docs for the load-side accounting caveat).
            assert!(fused.store_gbs > base.store_gbs, "size={s}");
            assert!(fused.total_gbs() > base.total_gbs(), "size={s}");
        }
    }

    #[test]
    fn fig15_memory_matches_paper_at_4096() {
        let g = rtx_3090ti_gpu();
        let pot = peak_memory_mb(&g, 4096, 4096, false);
        let map = peak_memory_mb(&g, 4096, 4096, true);
        assert!((map - 323.0).abs() < 15.0, "map={map}");
        let reduction = 1.0 - map / pot;
        assert!((reduction - 0.218).abs() < 0.05, "reduction={reduction}");
    }
}
