//! Tiling configurations and occupancy (paper §4.2, Fig. 8 sweep space).

use super::GpuConfig;

/// A kernel tile shape: `Tx × Ty` threads per block, `Ny` rows (part ②)
/// or row-steps (part ④) per thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileConfig {
    pub tx: usize,
    pub ty: usize,
    pub ny: usize,
}

impl TileConfig {
    pub fn threads_per_block(&self) -> usize {
        self.tx * self.ty
    }

    /// The paper's chosen configuration for part ② (Fig. 8: Tx=32, Ty=2, Ny=8).
    pub fn part2_default() -> Self {
        Self { tx: 32, ty: 2, ny: 8 }
    }

    /// The paper's chosen configuration for part ④ (Fig. 8: Tx=128, Ny=8).
    pub fn part4_default() -> Self {
        Self { tx: 128, ty: 1, ny: 8 }
    }
}

/// Resident blocks per SM: limited by the thread budget and the hardware
/// block-slot limit (16 on Ampere).
pub fn blocks_per_sm(cfg: &GpuConfig, tile: TileConfig) -> usize {
    let by_threads = cfg.max_threads_per_sm / tile.threads_per_block().max(1);
    by_threads.min(16).max(1)
}

/// Occupancy: resident threads / max threads per SM.
pub fn occupancy(cfg: &GpuConfig, tile: TileConfig) -> f64 {
    let resident = blocks_per_sm(cfg, tile) * tile.threads_per_block();
    (resident as f64 / cfg.max_threads_per_sm as f64).min(1.0)
}

/// Concurrent blocks across the device.
pub fn concurrent_blocks(cfg: &GpuConfig, tile: TileConfig) -> usize {
    cfg.sm_count * blocks_per_sm(cfg, tile)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::rtx_3090ti_gpu;

    #[test]
    fn one_warp_blocks_starve_the_sm() {
        let g = rtx_3090ti_gpu();
        // 32-thread blocks: 16-slot limit binds -> 512/1536 occupancy.
        let t32 = TileConfig { tx: 32, ty: 1, ny: 1 };
        assert_eq!(blocks_per_sm(&g, t32), 16);
        assert!((occupancy(&g, t32) - 512.0 / 1536.0).abs() < 1e-9);
        // 128-thread blocks reach full occupancy (12 * 128 = 1536).
        let t128 = TileConfig { tx: 128, ty: 1, ny: 1 };
        assert_eq!(blocks_per_sm(&g, t128), 12);
        assert!((occupancy(&g, t128) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn huge_blocks_reduce_block_slots() {
        let g = rtx_3090ti_gpu();
        let t = TileConfig { tx: 512, ty: 2, ny: 1 };
        assert_eq!(blocks_per_sm(&g, t), 1);
        assert!(occupancy(&g, t) < 0.7);
    }

    #[test]
    fn paper_defaults() {
        assert_eq!(TileConfig::part2_default().tx, 32);
        assert_eq!(TileConfig::part4_default().tx, 128);
    }
}
