//! Analytical GPU execution model (RTX 3090 Ti substitute).
//!
//! No CUDA hardware is available in this environment, so the paper's GPU
//! experiments (Figs. 5, 8, 13, 14, 15) are regenerated from a
//! transaction-level model of the two MAP-UOT kernels (paper Algorithms 2
//! and 3) and the CuPy baseline. The model captures the effects the paper
//! attributes its wins to:
//!
//! * **streaming traffic** per iteration (6·M·N elements baseline vs
//!   4·M·N for the two fused kernels — the GPU cannot fuse across the
//!   row-factor dependency, so MAP-UOT on GPU is two passes, not one);
//! * **occupancy** from the tile shape (`blocks_per_sm` is limited by the
//!   1536-thread SM and the 16-block slot limit; one-warp blocks starve
//!   the SM exactly as Fig. 8's `Tx=32` column shows);
//! * **fixed per-block-row latency** (shuffle/smem reduction + `atomicAdd`
//!   + `__syncthreads`) that larger `Ny` amortizes — the Fig. 8 rows;
//! * **atomic serialization chains** on `Sum_col`/`Sum_row` addresses;
//! * **host dispatch overhead** of the un-fused CuPy loop (many small
//!   kernel launches + Python) that dominates small sizes — the Fig. 13
//!   crossover at small matrices.
//!
//! Calibration constants live in `config::presets::rtx_3090ti_gpu`; the
//! model is validated in EXPERIMENTS.md against the shape of each figure
//! (who wins, optima locations, crossovers), not absolute microseconds.

pub mod model;
pub mod tiling;

pub use model::{
    mapuot_iter_ms, peak_memory_mb, pot_iter_ms, throughput_gbs, Throughput,
};
pub use tiling::{blocks_per_sm, occupancy, TileConfig};

/// GPU device parameters (Table 1 + calibrated micro-costs).
#[derive(Debug, Clone, Copy)]
pub struct GpuConfig {
    pub name: &'static str,
    pub peak_bw_gbs: f64,
    pub peak_gflops: f64,
    pub sm_count: usize,
    pub max_threads_per_sm: usize,
    pub warp_size: usize,
    /// Host-side cost of one kernel launch (µs).
    pub kernel_launch_us: f64,
    /// Hardware block-scheduling slot cost (ns).
    pub block_sched_ns: f64,
    /// Serialization cost per conflicting atomic on one address (ns).
    pub atomic_conflict_ns: f64,
    /// Per-step cost of a shared-memory/warp reduction (ns).
    pub smem_reduce_ns_per_step: f64,
    /// Framework/context device-memory overhead (MB) for Fig. 15.
    pub context_mb: f64,
}
