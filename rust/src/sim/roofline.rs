//! Global-memory Roofline model (paper §3.1, Fig. 3).
//!
//! Reproduces Eq. 1: the UOT algorithm's operational intensity is
//! `(M·N + M + N) / (4·M·N)` FLOP/byte (FP32) ≈ 1/4 — far below the ridge
//! points of both evaluation platforms (10.3 on the i9-12900K, 39.7 on the
//! RTX 3090 Ti), hence "heavily memory-bound".

use crate::algo::SolverKind;

/// A machine for Roofline purposes.
#[derive(Debug, Clone, Copy)]
pub struct Machine {
    pub name: &'static str,
    /// Peak FP32 throughput in GFLOP/s.
    pub peak_gflops: f64,
    /// Peak DRAM bandwidth in GB/s.
    pub peak_bw_gbs: f64,
}

impl Machine {
    /// Ridge point (FLOP/byte) where the machine turns compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.peak_gflops / self.peak_bw_gbs
    }

    /// Attainable GFLOP/s at operational intensity `i` (the roofline).
    pub fn attainable_gflops(&self, i: f64) -> f64 {
        (self.peak_bw_gbs * i).min(self.peak_gflops)
    }
}

/// Work `W` of one UOT iteration in operations (paper §3.1 counting: ADD,
/// MUL, DIV and pow CALL all count 1): `6·M·N + 6·(M+N)`.
pub fn work_ops(m: usize, n: usize) -> f64 {
    6.0 * (m as f64) * (n as f64) + 6.0 * (m as f64 + n as f64)
}

/// Memory traffic `Q` in bytes for one iteration of `kind` (FP32):
/// element accesses per element (POT 6, COFFEE 4, MAP-UOT 2) × M·N × 4 B.
pub fn traffic_bytes(kind: SolverKind, m: usize, n: usize) -> f64 {
    (kind.accesses_per_element() as f64) * (m as f64) * (n as f64) * 4.0
}

/// Operational intensity `I = W / Q` of one iteration of `kind`.
///
/// For the POT baseline this is Eq. 1: `(M·N + M + N) / (4·M·N)` ≈ 1/4.
/// MAP-UOT's single fused sweep triples it to ≈ 3/4 — still memory-bound,
/// which is why the paper's wins track the traffic ratio, not FLOPs.
pub fn operational_intensity(kind: SolverKind, m: usize, n: usize) -> f64 {
    work_ops(m, n) / traffic_bytes(kind, m, n)
}

/// Predicted time (seconds) for one iteration on `machine`, assuming the
/// kernel achieves `efficiency` of the roofline bound at its intensity.
pub fn predicted_iter_seconds(
    machine: &Machine,
    kind: SolverKind,
    m: usize,
    n: usize,
    efficiency: f64,
) -> f64 {
    let gflops = machine.attainable_gflops(operational_intensity(kind, m, n)) * efficiency;
    work_ops(m, n) / (gflops * 1e9)
}

/// One row of the Fig. 3 dataset.
#[derive(Debug, Clone)]
pub struct RooflineRow {
    pub machine: &'static str,
    pub kind: SolverKind,
    pub intensity: f64,
    pub attainable_gflops: f64,
    pub ridge_point: f64,
}

/// Build the Fig. 3 dataset for a list of machines.
pub fn figure3(machines: &[Machine], m: usize, n: usize) -> Vec<RooflineRow> {
    let mut rows = Vec::new();
    for mach in machines {
        for kind in SolverKind::ALL {
            let i = operational_intensity(kind, m, n);
            rows.push(RooflineRow {
                machine: mach.name,
                kind,
                intensity: i,
                attainable_gflops: mach.attainable_gflops(i),
                ridge_point: mach.ridge_point(),
            });
        }
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    #[test]
    fn eq1_is_about_one_quarter() {
        let i = operational_intensity(SolverKind::Pot, 1024, 1024);
        assert!((i - 0.25).abs() < 0.01, "I={i}");
        // exact form: (MN + M + N) / (4MN)
        let (m, n) = (64.0, 48.0);
        let exact = (m * n + m + n) / (4.0 * m * n);
        let got = operational_intensity(SolverKind::Pot, 64, 48);
        assert!((got - exact).abs() < 1e-12);
    }

    #[test]
    fn ridge_points_match_paper() {
        // 793.6 GFLOPS / 76.8 GB/s = 10.33; 40 TFLOPS / 1008 GB/s = 39.7.
        let cpu = presets::i9_12900k_roofline();
        let gpu = presets::rtx_3090ti_roofline();
        assert!((cpu.ridge_point() - 10.33).abs() < 0.05, "{}", cpu.ridge_point());
        assert!((gpu.ridge_point() - 39.7).abs() < 0.1, "{}", gpu.ridge_point());
    }

    #[test]
    fn mapuot_triples_intensity() {
        let pot = operational_intensity(SolverKind::Pot, 2048, 2048);
        let map = operational_intensity(SolverKind::MapUot, 2048, 2048);
        assert!((map / pot - 3.0).abs() < 1e-9);
    }

    #[test]
    fn both_platforms_memory_bound_for_all_kinds() {
        for mach in [presets::i9_12900k_roofline(), presets::rtx_3090ti_roofline()] {
            for kind in SolverKind::ALL {
                let i = operational_intensity(kind, 4096, 4096);
                assert!(i < mach.ridge_point(), "{:?} on {} not memory-bound", kind, mach.name);
                assert!(mach.attainable_gflops(i) < mach.peak_gflops);
            }
        }
    }

    #[test]
    fn predicted_time_scales_with_traffic() {
        let mach = presets::i9_12900k_roofline();
        let t_pot = predicted_iter_seconds(&mach, SolverKind::Pot, 4096, 4096, 1.0);
        let t_map = predicted_iter_seconds(&mach, SolverKind::MapUot, 4096, 4096, 1.0);
        assert!((t_pot / t_map - 3.0).abs() < 1e-6);
    }
}
