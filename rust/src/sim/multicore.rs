//! Multi-core scaling model — projected Fig. 10.
//!
//! This testbed has a single CPU core, so native thread-scaling cannot be
//! *measured* here; the figure is projected from the bandwidth-saturation
//! model the paper itself invokes (§5.2.2: "threads [are] not able to
//! release full computing performance as there are already enough memory
//! requests to fully saturate the bandwidth").
//!
//! Each solver's single-thread run achieves some DRAM bandwidth `b₁`;
//! `T` threads achieve `min(T·b₁, B_peak)`. The paper's measured plateaus
//! back-solve to exactly this: on the 12900K (76.8 GB/s), POT saturates at
//! 76.8/23.3 ≈ 3.3×, COFFEE at ≈ 4.0×, MAP-UOT at ≈ 7.2× — the three
//! end-points of Fig. 10. Speedups below are normalized to POT-1T like the
//! paper's.

use crate::algo::SolverKind;
use crate::sim::roofline::Machine;

/// Single-thread achieved DRAM bandwidth (GB/s) of each solver on the
/// 12900K, back-solved from the paper's Fig. 10 plateaus (see module doc).
/// MAP-UOT's is lowest *because* it does three times the work per byte —
/// which is exactly why it keeps scaling after the others hit the wall.
pub fn single_thread_bw_gbs(kind: SolverKind) -> f64 {
    match kind {
        SolverKind::Pot => 23.3,
        SolverKind::Coffee => 19.2,
        SolverKind::MapUot => 10.7,
    }
}

/// Projected time of one iteration (arbitrary units: bytes / GB/s) with
/// `threads` threads on `machine`.
pub fn iter_time_units(machine: &Machine, kind: SolverKind, m: usize, n: usize, threads: usize) -> f64 {
    let bytes = kind.accesses_per_element() as f64 * m as f64 * n as f64 * 4.0;
    let bw = (threads as f64 * single_thread_bw_gbs(kind)).min(machine.peak_bw_gbs);
    // Mild parallel-efficiency tail for thread launch/join + reduction
    // (Algorithm 1 lines 16-20): 1.5% per extra thread.
    let eff = 1.0 / (1.0 + 0.015 * (threads.saturating_sub(1)) as f64);
    bytes / (bw * eff)
}

/// Projected speedup of (`kind`, `threads`) vs POT single-thread (Fig. 10).
pub fn speedup_vs_pot1(machine: &Machine, kind: SolverKind, m: usize, n: usize, threads: usize) -> f64 {
    iter_time_units(machine, SolverKind::Pot, m, n, 1)
        / iter_time_units(machine, kind, m, n, threads)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets;

    const S: usize = 4096;

    #[test]
    fn fig10_plateaus_match_paper() {
        let m = presets::i9_12900k_roofline();
        // Paper: 16T speedups ~7.2x (MAP-UOT), ~4.0x (COFFEE), ~3.3x (POT).
        let map16 = speedup_vs_pot1(&m, SolverKind::MapUot, S, S, 16);
        let cof16 = speedup_vs_pot1(&m, SolverKind::Coffee, S, S, 16);
        let pot16 = speedup_vs_pot1(&m, SolverKind::Pot, S, S, 16);
        assert!((map16 - 7.2).abs() < 1.5, "map16={map16}");
        assert!((cof16 - 4.0).abs() < 1.0, "cof16={cof16}");
        assert!((pot16 - 3.3).abs() < 0.8, "pot16={pot16}");
        assert!(map16 > cof16 && cof16 > pot16);
    }

    #[test]
    fn scaling_monotone_until_saturation() {
        let m = presets::i9_12900k_roofline();
        let mut prev = 0.0;
        for t in [1usize, 2, 4, 8] {
            let s = speedup_vs_pot1(&m, SolverKind::MapUot, S, S, t);
            assert!(s > prev, "t={t}");
            prev = s;
        }
        // Saturated region: 8 -> 16 threads gains little.
        let s8 = speedup_vs_pot1(&m, SolverKind::MapUot, S, S, 8);
        let s16 = speedup_vs_pot1(&m, SolverKind::MapUot, S, S, 16);
        assert!(s16 / s8 < 1.15, "s8={s8} s16={s16}");
    }

    #[test]
    fn one_thread_ordering_matches_fig9() {
        let m = presets::i9_12900k_roofline();
        let map1 = speedup_vs_pot1(&m, SolverKind::MapUot, S, S, 1);
        // Single-thread MAP-UOT vs POT on the 12900K: paper avg 1.9x.
        assert!(map1 > 1.2 && map1 < 2.0, "map1={map1}");
    }
}
