//! MPI cluster model — Tianhe-1 scalability substitute (Fig. 16).
//!
//! The paper runs M=N=20480 on Tianhe-1 with mpi4py, replacing the
//! per-thread `NextSum_col` reduction (Algorithm 1 lines 16–20) with
//! `MPI_Allreduce`. The figure's shape is a race between two terms:
//!
//! * **compute**: each of `P` processes sweeps `M/P` rows; per-process
//!   effective rate is the min of its core-side issue rate and its share
//!   of the node's memory bandwidth (12 Westmere cores share one socket's
//!   DDR3 — the same saturation that flattens Fig. 10);
//! * **communication**: one allreduce of `N` floats per rescaling phase,
//!   costed with the Thakur–Rabenseifner–Gropp recursive-doubling /
//!   rec-halving model `2·log2(P)·α + 2·(P−1)/P · n·β`.
//!
//! POT needs two allreduces per iteration (column sums and a separate
//!   broadcast/reduce for the factor exchange of its unfused sweeps) and
//!   three times MAP-UOT's traffic; COFFEE needs one allreduce and twice
//!   the traffic, matching its sweep structure.

use crate::algo::SolverKind;

/// Cluster hardware model.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    /// MPI processes per node (the paper evaluates 8 and 12).
    pub procs_per_node: usize,
    /// Node-wide memory bandwidth shared by local processes (GB/s).
    pub node_bw_gbs: f64,
    /// Per-process compute-side issue rate, giga-elements/s of traffic.
    pub proc_gelems_per_s: f64,
    /// Per-link network bandwidth (GB/s).
    pub link_bw_gbs: f64,
    /// MPI latency term α (µs per message stage).
    pub alpha_us: f64,
    /// Per-iteration serial driver overhead (µs) — the mpi4py loop.
    pub py_overhead_us: f64,
}

impl ClusterConfig {
    /// Effective per-process matrix-traffic rate (elements/s) when `p`
    /// processes run on this node layout.
    pub fn per_proc_rate(&self, p: usize) -> f64 {
        let local = p.min(self.procs_per_node) as f64;
        let bw_share_elems = self.node_bw_gbs * 1e9 / 4.0 / local; // f32 elems/s
        (self.proc_gelems_per_s * 1e9).min(bw_share_elems)
    }

    /// Allreduce time (seconds) for `n` f32 values across `p` processes.
    pub fn allreduce_s(&self, n: usize, p: usize) -> f64 {
        if p <= 1 {
            return 0.0;
        }
        let lg = (p as f64).log2().ceil();
        let bytes = n as f64 * 4.0;
        2.0 * lg * self.alpha_us * 1e-6
            + 2.0 * (p as f64 - 1.0) / p as f64 * bytes / (self.link_bw_gbs * 1e9)
    }
}

/// Allreduces per iteration for each solver's distributed form.
fn allreduces_per_iter(kind: SolverKind) -> usize {
    match kind {
        SolverKind::Pot => 2,
        SolverKind::Coffee => 1,
        SolverKind::MapUot => 1,
    }
}

/// Predicted time (seconds) of one distributed iteration of `kind` with
/// `p` processes on an `m × n` problem.
pub fn iter_time_s(cfg: &ClusterConfig, kind: SolverKind, m: usize, n: usize, p: usize) -> f64 {
    let p = p.max(1);
    let rows = (m as f64 / p as f64).ceil();
    let traffic_elems = kind.accesses_per_element() as f64 * rows * n as f64;
    let compute = traffic_elems / cfg.per_proc_rate(p);
    let comm = allreduces_per_iter(kind) as f64 * cfg.allreduce_s(n, p);
    compute + comm + cfg.py_overhead_us * 1e-6
}

/// Speedup of (`kind`, `p` procs) relative to single-process POT — the
/// normalization Fig. 16 uses.
pub fn speedup_vs_pot1(cfg: &ClusterConfig, kind: SolverKind, m: usize, n: usize, p: usize) -> f64 {
    iter_time_s(cfg, SolverKind::Pot, m, n, 1) / iter_time_s(cfg, kind, m, n, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::tianhe1_cluster;

    const M: usize = 20480;

    #[test]
    fn allreduce_grows_logarithmically_in_latency_term() {
        let c = tianhe1_cluster(12);
        let t2 = c.allreduce_s(1, 2);
        let t1024 = c.allreduce_s(1, 1024);
        assert!(t1024 < t2 * 12.0, "t2={t2} t1024={t1024}");
        assert!(t1024 > t2);
    }

    #[test]
    fn fig16_ordering_mapuot_coffee_pot() {
        let c = tianhe1_cluster(12);
        for p in [48usize, 192, 768] {
            let s_map = speedup_vs_pot1(&c, SolverKind::MapUot, M, M, p);
            let s_cof = speedup_vs_pot1(&c, SolverKind::Coffee, M, M, p);
            let s_pot = speedup_vs_pot1(&c, SolverKind::Pot, M, M, p);
            assert!(s_map > s_cof && s_cof > s_pot, "p={p}: {s_map} {s_cof} {s_pot}");
        }
    }

    #[test]
    fn fig16_magnitudes_in_paper_band() {
        // Paper at 768 procs: MAP 550x, COFFEE 301x, POT 184x.
        let c = tianhe1_cluster(12);
        let s_map = speedup_vs_pot1(&c, SolverKind::MapUot, M, M, 768);
        let s_pot = speedup_vs_pot1(&c, SolverKind::Pot, M, M, 768);
        assert!(s_map > 350.0 && s_map < 900.0, "map={s_map}");
        assert!(s_pot > 120.0 && s_pot < 400.0, "pot={s_pot}");
        assert!(s_map / s_pot > 2.0, "ratio={}", s_map / s_pot);
    }

    #[test]
    fn scaling_is_monotone_then_comm_bound() {
        let c = tianhe1_cluster(8);
        let mut prev = 0.0;
        for p in [8usize, 32, 128, 512] {
            let s = speedup_vs_pot1(&c, SolverKind::MapUot, M, M, p);
            assert!(s > prev, "p={p}: {s} <= {prev}");
            prev = s;
        }
        // Communication eventually dominates: efficiency per proc drops.
        let e512 = speedup_vs_pot1(&c, SolverKind::MapUot, M, M, 512) / 512.0;
        let e8 = speedup_vs_pot1(&c, SolverKind::MapUot, M, M, 8) / 8.0;
        assert!(e512 < e8, "e512={e512} e8={e8}");
    }

    #[test]
    fn node_bandwidth_saturation_binds() {
        let c = tianhe1_cluster(12);
        // With 12 procs on one node each gets 1/12 of 25.6 GB/s.
        let r12 = c.per_proc_rate(12);
        let r1 = c.per_proc_rate(1);
        assert!(r12 < r1);
        assert!((r12 - 25.6e9 / 4.0 / 12.0).abs() < 1.0);
    }
}
