//! # MAP-UOT — memory-efficient unbalanced optimal transport
//!
//! Reproduction of *"MAP-UOT: A Memory-Efficient Approach to Unbalanced
//! Optimal Transport Implementation"* (Sun, Hu, Jiang; 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the solver service: native solvers
//!   ([`algo`]: POT baseline, COFFEE comparator, the fused MAP-UOT
//!   iteration, threaded variants), a request [`coordinator`] with dynamic
//!   batching, a PJRT [`runtime`] executing AOT artifacts, the paper's
//!   applications ([`apps`]), and the simulators ([`sim`]) that regenerate
//!   the hardware-gated figures (cache misses, GPU throughput, Tianhe-1).
//! * **L2 (build time)** — `python/compile/model.py`: the UOT chunk graph
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (build time)** — `python/compile/kernels/mapuot.py`: the fused
//!   interweaved iteration as a Pallas kernel.
//!
//! Quickstart — build a [`SolverSession`] once, solve many times. The
//! session owns every scratch buffer (see [`algo::Workspace`] for the
//! allocation contract), tracks `plan_delta` inside the fused sweep
//! instead of snapshotting the plan, and can report progress or cancel
//! through a [`algo::ConvergenceObserver`]. With `.threads(t)` the session
//! also owns a persistent worker pool ([`algo::pool`]): workers spawn once
//! at build time and every iteration dispatches to them over an epoch
//! barrier — zero thread spawns and zero heap allocations per solve after
//! warmup, serial or threaded:
//!
//! ```no_run
//! use map_uot::algo::{CheckEvent, ObserverAction, Problem, SolverKind, SolverSession, StopRule};
//!
//! let problem = Problem::random(512, 512, 0.7, 42);
//! let mut session = SolverSession::builder(SolverKind::MapUot)
//!     .threads(1)
//!     .stop(StopRule { tol: 1e-4, delta_tol: 1e-6, max_iter: 2000 })
//!     .observer(|ev: CheckEvent| {
//!         println!("iter {:4}  err={:.3e}  delta={:.3e}", ev.iters, ev.err, ev.delta);
//!         ObserverAction::Continue
//!     })
//!     .build(&problem);
//!
//! let report = session.solve(&problem)?;
//! println!("converged={} iters={} err={}", report.converged, report.iters, report.err);
//! let _plan = session.plan(); // borrow the result, no clone
//!
//! // Steady state: same-shape re-solves reuse every buffer (zero heap
//! // allocations after warmup), and batches share one workspace.
//! let more: Vec<Problem> = (0..8).map(|s| Problem::random(512, 512, 0.7, s)).collect();
//! for outcome in session.solve_batch(&more) {
//!     let (plan, report) = outcome?;
//!     # let _ = (plan, report);
//! }
//! # Ok::<(), map_uot::Error>(())
//! ```
//!
//! ## Correctness tooling
//!
//! The `unsafe` surface (SIMD kernels, the pool's disjoint-access views)
//! is machine-checked: `cargo run -p uotlint` enforces the SAFETY-comment,
//! hot-path-allocation, and thread/intrinsic-encapsulation contracts
//! statically, and CI runs Miri, ThreadSanitizer, and AddressSanitizer
//! legs over the pool/kernel test subsets. See `EXPERIMENTS.md`
//! §Correctness tooling for how to run each gate locally.

// Unsafe blocks inside unsafe fns must be explicit (and carry their own
// SAFETY comments — enforced by tools/uotlint).
#![deny(unsafe_op_in_unsafe_fn)]

pub mod algo;
pub mod apps;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod util;
pub mod xla_stub;

pub use algo::{
    solver_for, AffinityHint, CheckEvent, ConvergenceObserver, CostKind, CsrMatrix, GeomProblem,
    ObserverAction, ParallelBackend, Problem, SolveOptions, Solver, SolverKind, SolverSession,
    SparseProblem, ThreadPool, Workspace,
};
pub use error::{Error, Result};

#[allow(deprecated)]
pub use algo::solve;
