//! # MAP-UOT — memory-efficient unbalanced optimal transport
//!
//! Reproduction of *"MAP-UOT: A Memory-Efficient Approach to Unbalanced
//! Optimal Transport Implementation"* (Sun, Hu, Jiang; 2024) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the solver service: native solvers
//!   ([`algo`]: POT baseline, COFFEE comparator, the fused MAP-UOT
//!   iteration, threaded variants), a request [`coordinator`] with dynamic
//!   batching, a PJRT [`runtime`] executing AOT artifacts, the paper's
//!   applications ([`apps`]), and the simulators ([`sim`]) that regenerate
//!   the hardware-gated figures (cache misses, GPU throughput, Tianhe-1).
//! * **L2 (build time)** — `python/compile/model.py`: the UOT chunk graph
//!   AOT-lowered to HLO text in `artifacts/`.
//! * **L1 (build time)** — `python/compile/kernels/mapuot.py`: the fused
//!   interweaved iteration as a Pallas kernel.
//!
//! Quickstart:
//!
//! ```no_run
//! use map_uot::algo::{solve, Problem, SolverKind, SolveOptions};
//!
//! let problem = Problem::random(512, 512, 0.7, 42);
//! let (plan, report) = solve(SolverKind::MapUot, &problem, SolveOptions::default());
//! println!("converged={} iters={} err={}", report.converged, report.iters, report.err);
//! # let _ = plan;
//! ```

pub mod algo;
pub mod apps;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod util;

pub use algo::{solve, Problem, SolveOptions, SolverKind};
pub use error::{Error, Result};
