//! In-band solver telemetry: one monotonic clock, an alloc-free per-thread
//! span recorder, analytic roofline counters, and trace exporters.
//!
//! # The overhead contract
//!
//! * **Disabled tracing is free.** Every record entry point checks one
//!   `Relaxed` atomic flag ([`enabled`]) before doing anything else, so a
//!   solve with tracing off pays one predictable branch per span site —
//!   all sites sit at check-burst granularity, never per element.
//! * **Enabled tracing is alloc-free after warmup.** A thread's first
//!   recorded span registers a fixed-capacity ring ([`RING_CAP`] slots of
//!   three `AtomicU64`s) in the process-wide lane registry — that is the
//!   one documented warmup allocation. Every later record is a
//!   thread-local lookup plus three relaxed stores: no locks, no heap,
//!   legal inside the uotlint-guarded hot loops (the `telemetry` lint
//!   rule additionally pins hot files to this alloc-free API surface).
//! * **Overflow overwrites, never blocks.** The ring keeps the most
//!   recent [`RING_CAP`] spans per lane; older spans are overwritten and
//!   counted in [`lost_spans`]. Threads past the [`MAX_LANES`] cap (only
//!   reachable by churning ephemeral scope-engine threads) drop their
//!   spans silently — recording is best-effort by design.
//!
//! Drains ([`snapshot_spans`]) are cold paths intended for quiescent
//! moments (after a solve, at service shutdown); a drain racing a live
//! recorder may skip slots being overwritten mid-read, which the
//! per-slot sequence tag detects.
//!
//! The clock ([`now_ns`]) is the single monotonic source for the whole
//! crate — `util::timer::Timer` and the span recorder share it, so bench
//! timings and trace timestamps are directly comparable.

use std::cell::OnceCell;
use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::time::Instant;

// --- clock ------------------------------------------------------------------

/// Process-wide clock anchor, pinned on first use (module scope keeps the
/// `OnceLock::new()` call out of `now_ns`'s body, which must stay free of
/// constructor calls for the uotlint call-graph allocation rule).
static ANCHOR: OnceLock<Instant> = OnceLock::new();

/// Nanoseconds since the process-wide clock anchor (first use).
///
/// Monotonic, alloc-free, and shared by `util::timer::Timer`, the span
/// recorder, and the exporters — one clock source for the whole crate.
#[inline]
pub fn now_ns() -> u64 {
    ANCHOR.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

// --- enable flag ------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One `Relaxed` load — the cold-flag branch every
/// record path takes first.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (process-wide). Enabling pins the clock
/// anchor so the first span does not pay the one-time init.
pub fn set_enabled(on: bool) {
    if on {
        let _ = now_ns();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

// --- phases -----------------------------------------------------------------

/// The per-sweep phase a span covers. Every backend maps its work onto
/// this fixed vocabulary so traces are comparable across dense / CSR /
/// matfree / oned / fp64 and across the serial, scope and pool engines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Phase {
    /// Kernel/state (re)generation: matfree row regeneration seeding,
    /// oned sorted-support preparation, warm-start seeding.
    KernelGenerate = 0,
    /// The fused scaling sweep itself (a burst of `check_every`
    /// iterations, or one pool worker's part of it).
    FusedSweep = 1,
    /// Cross-part reduction of partial column sums on the threaded
    /// engines.
    Reduction = 2,
    /// Marginal-error evaluation at a check boundary.
    ConvergenceCheck = 3,
    /// A whole solve, dispatch to report (the envelope span).
    Solve = 4,
}

impl Phase {
    pub const ALL: [Phase; 5] = [
        Phase::KernelGenerate,
        Phase::FusedSweep,
        Phase::Reduction,
        Phase::ConvergenceCheck,
        Phase::Solve,
    ];

    /// Stable lowercase name used by both exporters (part of the trace
    /// schema — do not rename without bumping consumers).
    pub fn name(self) -> &'static str {
        match self {
            Phase::KernelGenerate => "kernel_generate",
            Phase::FusedSweep => "fused_sweep",
            Phase::Reduction => "reduction",
            Phase::ConvergenceCheck => "convergence_check",
            Phase::Solve => "solve",
        }
    }

    fn from_u8(v: u8) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| *p as u8 == v)
    }
}

// --- the per-thread ring ----------------------------------------------------

/// Spans kept per lane; older spans are overwritten (power of two).
pub const RING_CAP: usize = 1024;

/// Hard cap on registered lanes. Persistent threads (main, pool workers,
/// service workers) register well under this; only churning ephemeral
/// scope-engine threads can exhaust it, after which their spans drop.
pub const MAX_LANES: usize = 64;

#[derive(Default)]
struct Slot {
    /// `(seq + 1) << 8 | phase`; 0 = never written. The sequence tag lets
    /// a drain detect slots overwritten while being read.
    meta: AtomicU64,
    start: AtomicU64,
    end: AtomicU64,
}

struct ThreadRing {
    lane: u32,
    /// Monotonic count of spans ever recorded on this lane; the slot for
    /// span `seq` is `seq & (RING_CAP - 1)`.
    head: AtomicU64,
    slots: Vec<Slot>,
}

impl ThreadRing {
    // uotlint: allow(alloc) — ring construction is the recorder's one
    // documented warmup allocation, never on the steady-state record path.
    fn new(lane: u32) -> Self {
        let mut slots = Vec::with_capacity(RING_CAP);
        slots.resize_with(RING_CAP, Slot::default);
        Self { lane, head: AtomicU64::new(0), slots }
    }

    #[inline]
    fn push(&self, phase: Phase, start_ns: u64, end_ns: u64) {
        let seq = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(seq as usize) & (RING_CAP - 1)];
        slot.start.store(start_ns, Ordering::Relaxed);
        slot.end.store(end_ns, Ordering::Relaxed);
        slot.meta.store(((seq + 1) << 8) | phase as u64, Ordering::Release);
    }
}

/// Mutex poison recovery (the `coordinator::batcher::recover` pattern):
/// the registry holds plain `Arc` handles, valid at every observable
/// point, so a panicked holder loses nothing.
fn recover<T>(r: Result<T, PoisonError<T>>) -> T {
    r.unwrap_or_else(PoisonError::into_inner)
}

// uotlint: allow(alloc) — one-time registry construction (warmup path).
fn registry() -> &'static Mutex<Vec<Arc<ThreadRing>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadRing>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::with_capacity(MAX_LANES)))
}

// uotlint: allow(alloc) — lane registration is the recorder's documented
// warmup allocation; it runs once per thread, never on the record path.
fn register() -> Option<Arc<ThreadRing>> {
    let mut lanes = recover(registry().lock());
    if lanes.len() >= MAX_LANES {
        return None;
    }
    let ring = Arc::new(ThreadRing::new(lanes.len() as u32));
    lanes.push(Arc::clone(&ring));
    Some(ring)
}

thread_local! {
    static RING: OnceCell<Option<Arc<ThreadRing>>> = const { OnceCell::new() };
}

/// Record one finished span on the calling thread's lane.
///
/// The alloc-free hot entry point: a cold-flag branch when disabled; a
/// thread-local lookup plus three relaxed stores when enabled (after the
/// thread's one-time lane registration).
#[inline]
pub fn record_span(phase: Phase, start_ns: u64, end_ns: u64) {
    if !enabled() {
        return;
    }
    let _ = RING.try_with(|cell| {
        if let Some(ring) = cell.get_or_init(register) {
            ring.push(phase, start_ns, end_ns);
        }
    });
}

/// RAII span: records `phase` from construction to drop. When tracing is
/// disabled both ends are a single cold-flag branch.
pub struct SpanGuard {
    phase: Phase,
    start_ns: u64,
    armed: bool,
}

/// Open a span over the enclosing scope (see [`SpanGuard`]).
#[inline]
pub fn span(phase: Phase) -> SpanGuard {
    if enabled() {
        SpanGuard { phase, start_ns: now_ns(), armed: true }
    } else {
        SpanGuard { phase, start_ns: 0, armed: false }
    }
}

impl Drop for SpanGuard {
    #[inline]
    fn drop(&mut self) {
        if self.armed {
            record_span(self.phase, self.start_ns, now_ns());
        }
    }
}

// --- drain / export (cold paths) --------------------------------------------

/// One drained span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Registration-order lane id of the recording thread.
    pub lane: u32,
    /// Per-lane monotonic sequence number.
    pub seq: u64,
    pub phase: Phase,
    pub start_ns: u64,
    pub end_ns: u64,
}

/// Collect every lane's retained spans, sorted by start time. Cold,
/// non-destructive; intended for quiescent moments (slots overwritten
/// mid-read are skipped via their sequence tags).
// uotlint: allow(alloc) — cold drain path, never called from hot roots.
pub fn snapshot_spans() -> Vec<SpanEvent> {
    let lanes = recover(registry().lock());
    let mut out = Vec::new();
    for ring in lanes.iter() {
        let head = ring.head.load(Ordering::Acquire);
        let kept = head.min(RING_CAP as u64);
        for seq in (head - kept)..head {
            let slot = &ring.slots[(seq as usize) & (RING_CAP - 1)];
            let meta = slot.meta.load(Ordering::Acquire);
            if meta >> 8 != seq + 1 {
                continue; // empty, overwritten, or torn mid-record
            }
            let Some(phase) = Phase::from_u8((meta & 0xff) as u8) else {
                continue;
            };
            out.push(SpanEvent {
                lane: ring.lane,
                seq,
                phase,
                start_ns: slot.start.load(Ordering::Relaxed),
                end_ns: slot.end.load(Ordering::Relaxed),
            });
        }
    }
    out.sort_by_key(|e| (e.start_ns, e.lane, e.seq));
    out
}

/// Spans overwritten before any drain saw them, across all lanes.
pub fn lost_spans() -> u64 {
    let lanes = recover(registry().lock());
    let mut lost = 0u64;
    for ring in lanes.iter() {
        lost += ring.head.load(Ordering::Relaxed).saturating_sub(RING_CAP as u64);
    }
    lost
}

/// Registered lanes (threads that have recorded at least one span).
pub fn lane_count() -> usize {
    recover(registry().lock()).len()
}

/// Clear every lane's retained spans and sequence counters. Lanes stay
/// registered (the warmup allocation is kept). Cold.
pub fn reset() {
    let lanes = recover(registry().lock());
    for ring in lanes.iter() {
        ring.head.store(0, Ordering::Release);
        for slot in ring.slots.iter() {
            slot.meta.store(0, Ordering::Release);
        }
    }
}

/// Export `events` to `path`: a JSONL event log when the path ends in
/// `.jsonl`, otherwise a chrome://tracing (Perfetto "trace event") JSON
/// array loadable by `chrome://tracing` and `ui.perfetto.dev`.
// uotlint: allow(alloc) — cold export path, never called from hot roots.
pub fn export_trace(path: &str, events: &[SpanEvent]) -> io::Result<()> {
    let body =
        if path.ends_with(".jsonl") { render_jsonl(events) } else { render_perfetto(events) };
    std::fs::write(path, body)
}

/// One JSON object per line: `lane`, `seq`, `phase`, `start_ns`, `end_ns`.
// uotlint: allow(alloc) — cold export path, never called from hot roots.
pub fn render_jsonl(events: &[SpanEvent]) -> String {
    let mut out = String::new();
    for e in events {
        out.push_str(&format!(
            "{{\"lane\":{},\"seq\":{},\"phase\":\"{}\",\"start_ns\":{},\"end_ns\":{}}}\n",
            e.lane,
            e.seq,
            e.phase.name(),
            e.start_ns,
            e.end_ns
        ));
    }
    out
}

/// Chrome trace-event JSON: complete (`"ph":"X"`) events, microsecond
/// timestamps, one `tid` per lane. The schema [`validate_perfetto`]
/// checks is exactly what this emits.
// uotlint: allow(alloc) — cold export path, never called from hot roots.
pub fn render_perfetto(events: &[SpanEvent]) -> String {
    let mut out = String::from("[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ts = e.start_ns as f64 / 1e3;
        let dur = e.end_ns.saturating_sub(e.start_ns) as f64 / 1e3;
        out.push_str(&format!(
            "\n{{\"name\":\"{}\",\"cat\":\"mapuot\",\"ph\":\"X\",\"ts\":{ts:.3},\
             \"dur\":{dur:.3},\"pid\":1,\"tid\":{}}}",
            e.phase.name(),
            e.lane
        ));
    }
    out.push_str("\n]\n");
    out
}

/// Minimal schema check for an exported Perfetto trace: a JSON array of
/// objects, each carrying `name`, `ph:"X"`, `ts`, `dur`, `pid`, `tid`.
/// Returns the event count. This is the check the golden trace test and
/// the CI traced-solve leg run against fresh exports.
// uotlint: allow(alloc) — cold validation path, never called from hot roots.
pub fn validate_perfetto(json: &str) -> Result<usize, String> {
    let t = json.trim();
    if !t.starts_with('[') || !t.ends_with(']') {
        return Err("not a JSON array".to_string());
    }
    let mut events = 0usize;
    let mut depth = 0usize;
    let mut in_str = false;
    let mut esc = false;
    let mut obj_start = 0usize;
    for (i, ch) in t.char_indices() {
        if in_str {
            if esc {
                esc = false;
            } else if ch == '\\' {
                esc = true;
            } else if ch == '"' {
                in_str = false;
            }
            continue;
        }
        match ch {
            '"' => in_str = true,
            '{' => {
                depth += 1;
                if depth == 1 {
                    obj_start = i;
                }
            }
            '}' => {
                if depth == 0 {
                    return Err(format!("unbalanced braces at byte {i}"));
                }
                depth -= 1;
                if depth == 0 {
                    let obj = &t[obj_start..=i];
                    for key in
                        ["\"name\":", "\"ph\":\"X\"", "\"ts\":", "\"dur\":", "\"pid\":", "\"tid\":"]
                    {
                        if !obj.contains(key) {
                            return Err(format!("event {events} missing {key}"));
                        }
                    }
                    events += 1;
                }
            }
            _ => {}
        }
    }
    if depth != 0 || in_str {
        return Err("unterminated object or string".to_string());
    }
    Ok(events)
}

// --- roofline counters ------------------------------------------------------

/// Analytic per-solve traffic/compute estimate, derived from the solver's
/// pass/access accounting (`SolverKind::passes_per_iter` /
/// `accesses_per_element`) rather than runtime counters — so the hot
/// loops stay untouched and the estimate is exact for the streaming
/// model the paper's roofline (Fig. 3) uses.
///
/// Flop counts are the documented estimate `2 × element accesses +
/// 16 × exp evaluations` (fused multiply-add per element, degree-5
/// polynomial + range reduction per transcendental).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    /// DRAM bytes touched across the solve.
    pub bytes: f64,
    /// Matrix-element visits (elements × passes × iterations).
    pub element_passes: f64,
    /// Transcendental (exp) evaluations (matfree/oned regeneration).
    pub exp_evals: f64,
    /// Plan/state element stores (the read+write share of the passes).
    pub plan_stores: f64,
    /// Estimated floating-point operations.
    pub flops: f64,
}

impl Roofline {
    /// Materialized sweep (dense or CSR): `elems` stored matrix elements
    /// of `bytes_per_elem` bytes, walked `passes` times per iteration
    /// with `accesses` DRAM accesses per element per iteration.
    pub fn materialized(
        elems: u64,
        passes: u64,
        accesses: u64,
        bytes_per_elem: u64,
        iters: u64,
    ) -> Self {
        let it = iters as f64;
        let e = elems as f64;
        let element_passes = e * passes as f64 * it;
        let bytes = e * accesses as f64 * bytes_per_elem as f64 * it;
        let plan_stores = e * accesses.saturating_sub(passes) as f64 * it;
        Roofline { bytes, element_passes, exp_evals: 0.0, plan_stores, flops: 2.0 * element_passes }
    }

    /// Materialization-free sweep: kernel entries regenerated on the fly
    /// (one exp per element per iteration), resident state O(m + n).
    pub fn regenerated(m: u64, n: u64, iters: u64) -> Self {
        let it = iters as f64;
        let e = (m as f64) * (n as f64);
        let element_passes = e * it;
        let exp_evals = e * it;
        // Streamed state per iteration: u, v, fcol, colsum, rowsum, and
        // the two marginals — ~7 f32 vectors of O(m + n).
        let bytes = (m + n) as f64 * 7.0 * 4.0 * it;
        Roofline {
            bytes,
            element_passes,
            exp_evals,
            plan_stores: 0.0,
            flops: 2.0 * element_passes + 16.0 * exp_evals,
        }
    }

    /// Exact 1D fast path: O(m + n) work per iteration via the
    /// prefix/suffix decay recursions (two exp-decay factors per event),
    /// f64 accumulator state of 24 bytes per point.
    pub fn oned(m: u64, n: u64, iters: u64) -> Self {
        let it = iters as f64;
        let e = (m + n) as f64;
        let element_passes = e * it;
        let exp_evals = 2.0 * e * it;
        let bytes = e * 24.0 * it;
        Roofline {
            bytes,
            element_passes,
            exp_evals,
            plan_stores: 0.0,
            flops: 4.0 * element_passes + 16.0 * exp_evals,
        }
    }

    /// Arithmetic intensity, flop per DRAM byte (the roofline x-axis).
    pub fn intensity(&self) -> f64 {
        if self.bytes > 0.0 {
            self.flops / self.bytes
        } else {
            0.0
        }
    }

    /// Achieved DRAM bandwidth for a solve of `seconds` (the live
    /// roofline y-axis proxy for a memory-bound kernel).
    pub fn bandwidth_gbs(&self, seconds: f64) -> f64 {
        if seconds > 0.0 {
            self.bytes / seconds / 1e9
        } else {
            0.0
        }
    }

    /// The CLI report line: live arithmetic intensity + achieved
    /// bandwidth + the raw counters.
    // uotlint: allow(alloc) — cold report formatting, never on hot paths.
    pub fn cli_line(&self, seconds: f64) -> String {
        format!(
            "roofline: {:.3} GB touched | {:.2} GB/s | AI {:.4} flop/B | {:.3e} elem passes | \
             {:.3e} exp evals | {:.3e} plan stores",
            self.bytes / 1e9,
            self.bandwidth_gbs(seconds),
            self.intensity(),
            self.element_passes,
            self.exp_evals,
            self.plan_stores
        )
    }
}

/// Serializes lib tests that mutate the process-wide recorder state (the
/// enable flag, the lane registry): any test anywhere in the crate that
/// calls [`set_enabled`] or [`reset`] must hold this guard.
/// Poison-tolerant — assertions may fire while held.
#[cfg(test)]
pub(crate) fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    recover(LOCK.lock())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_lock() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    /// Sentinel start timestamps far above any real clock reading, so
    /// concurrent lib tests recording real spans never collide.
    const SENTINEL: u64 = 1 << 62;

    #[test]
    fn clock_is_monotonic_and_shared() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a);
    }

    #[test]
    fn disabled_recording_is_a_no_op() {
        let _g = test_lock();
        set_enabled(false);
        let before = snapshot_spans().len();
        record_span(Phase::FusedSweep, SENTINEL, SENTINEL + 1);
        let guard = span(Phase::Reduction);
        drop(guard);
        assert_eq!(snapshot_spans().len(), before);
    }

    #[test]
    fn spans_record_and_drain_in_order() {
        let _g = test_lock();
        set_enabled(true);
        for k in 0..4u64 {
            record_span(Phase::ConvergenceCheck, SENTINEL + 10 * k, SENTINEL + 10 * k + 5);
        }
        set_enabled(false);
        let mine: Vec<SpanEvent> = snapshot_spans()
            .into_iter()
            .filter(|e| e.start_ns >= SENTINEL && e.phase == Phase::ConvergenceCheck)
            .collect();
        assert_eq!(mine.len(), 4, "{mine:?}");
        assert!(mine.windows(2).all(|w| w[0].start_ns < w[1].start_ns));
        assert!(mine.windows(2).all(|w| w[0].lane == w[1].lane), "one thread, one lane");
        assert_eq!(mine[0].end_ns - mine[0].start_ns, 5);
        reset();
        assert!(snapshot_spans().iter().all(|e| e.start_ns < SENTINEL));
    }

    #[test]
    fn ring_wraps_and_counts_lost_spans() {
        let _g = test_lock();
        set_enabled(true);
        reset();
        let extra = 10u64;
        for k in 0..(RING_CAP as u64 + extra) {
            record_span(Phase::FusedSweep, SENTINEL + k, SENTINEL + k + 1);
        }
        set_enabled(false);
        let mine: Vec<SpanEvent> = snapshot_spans()
            .into_iter()
            .filter(|e| e.start_ns >= SENTINEL && e.phase == Phase::FusedSweep)
            .collect();
        // Exactly the most recent RING_CAP survive; the first `extra`
        // were overwritten.
        assert_eq!(mine.len(), RING_CAP, "wrap keeps the newest CAP spans");
        assert_eq!(mine.first().map(|e| e.seq), Some(extra));
        assert_eq!(mine.last().map(|e| e.seq), Some(RING_CAP as u64 + extra - 1));
        assert!(lost_spans() >= extra);
        reset();
    }

    #[test]
    fn span_guard_records_on_drop() {
        let _g = test_lock();
        set_enabled(true);
        reset();
        {
            let _s = span(Phase::Solve);
        }
        set_enabled(false);
        let got = snapshot_spans().into_iter().any(|e| e.phase == Phase::Solve);
        assert!(got, "guard drop recorded the span");
        reset();
    }

    #[test]
    fn jsonl_and_perfetto_renderers() {
        let events = [
            SpanEvent { lane: 0, seq: 0, phase: Phase::FusedSweep, start_ns: 1000, end_ns: 3500 },
            SpanEvent { lane: 2, seq: 1, phase: Phase::Reduction, start_ns: 3500, end_ns: 4000 },
        ];
        let jsonl = render_jsonl(&events);
        assert_eq!(jsonl.lines().count(), 2);
        assert!(jsonl.starts_with("{\"lane\":0,\"seq\":0,\"phase\":\"fused_sweep\","));
        assert!(jsonl.contains("\"start_ns\":3500"));

        let perfetto = render_perfetto(&events);
        assert_eq!(validate_perfetto(&perfetto), Ok(2));
        assert!(perfetto.contains("\"name\":\"reduction\""));
        assert!(perfetto.contains("\"ts\":1.000"));
        assert!(perfetto.contains("\"dur\":2.500"));
        assert!(perfetto.contains("\"tid\":2"));
    }

    #[test]
    fn perfetto_validator_rejects_malformed_traces() {
        assert!(validate_perfetto("{}").is_err(), "not an array");
        assert!(validate_perfetto("[{\"name\":\"x\"}]").is_err(), "missing keys");
        assert!(validate_perfetto("[{\"name\":").is_err(), "truncated");
        assert_eq!(validate_perfetto("[]"), Ok(0));
        // Brace characters inside strings must not confuse the scanner.
        let tricky = "[\n{\"name\":\"a{b}\",\"cat\":\"m\",\"ph\":\"X\",\"ts\":0.0,\
                      \"dur\":1.0,\"pid\":1,\"tid\":0}\n]";
        assert_eq!(validate_perfetto(tricky), Ok(1));
    }

    #[test]
    fn roofline_dense_math() {
        // MAP-UOT 64x32, 10 iters: 1 pass, 2 accesses per element.
        let r = Roofline::materialized(64 * 32, 1, 2, 4, 10);
        assert_eq!(r.element_passes, 64.0 * 32.0 * 10.0);
        assert_eq!(r.bytes, 64.0 * 32.0 * 2.0 * 4.0 * 10.0);
        assert_eq!(r.plan_stores, 64.0 * 32.0 * 10.0, "one rw pass");
        assert_eq!(r.flops, 2.0 * r.element_passes);
        assert!((r.intensity() - 0.25).abs() < 1e-12, "2 flops / 8 bytes");
        assert!((r.bandwidth_gbs(1.0) - r.bytes / 1e9).abs() < 1e-12);
        // POT at the same shape touches 3x the bytes of MAP-UOT.
        let pot = Roofline::materialized(64 * 32, 4, 6, 4, 10);
        assert!((pot.bytes / r.bytes - 3.0).abs() < 1e-12);
    }

    #[test]
    fn roofline_regenerated_and_oned_math() {
        let r = Roofline::regenerated(100, 50, 4);
        assert_eq!(r.exp_evals, 100.0 * 50.0 * 4.0);
        assert_eq!(r.plan_stores, 0.0);
        assert_eq!(r.bytes, 150.0 * 7.0 * 4.0 * 4.0);
        let o = Roofline::oned(1000, 1000, 8);
        assert_eq!(o.element_passes, 2000.0 * 8.0);
        assert_eq!(o.bytes, 2000.0 * 24.0 * 8.0);
        // Regeneration is compute-dense: far higher AI than a dense sweep.
        assert!(r.intensity() > Roofline::materialized(5000, 1, 2, 4, 4).intensity());
        let line = r.cli_line(0.5);
        assert!(line.starts_with("roofline:"), "{line}");
        assert!(line.contains("GB/s"));
    }

    #[test]
    fn roofline_degenerate_inputs_are_total() {
        let r = Roofline::materialized(0, 1, 2, 4, 0);
        assert_eq!(r.intensity(), 0.0);
        assert_eq!(r.bandwidth_gbs(0.0), 0.0);
        // accesses < passes saturates instead of wrapping.
        let w = Roofline::materialized(10, 4, 1, 4, 1);
        assert_eq!(w.plan_stores, 0.0);
    }
}
