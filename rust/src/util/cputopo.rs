//! CPU cache-topology detection for the kernel tiling policy.
//!
//! The tiled fused sweep (`algo::kernels`) sizes its column panels so that
//! `Factor_col` + `inv_fcol` + `NextSum_col` + a row panel stay L1-resident,
//! its row chunks so a chunk stays L2-resident between the two phases, and
//! its non-temporal-store threshold so streaming stores only engage once the
//! plan exceeds the last-level cache (below that, regular stores keep the
//! matrix cache-resident across iterations, which is strictly better).
//!
//! Detection reads the Linux sysfs cache hierarchy
//! (`/sys/devices/system/cpu/cpu0/cache/index*/`), which works unprivileged
//! in containers; anything missing or unparsable falls back to conservative
//! defaults (32 KiB L1d / 512 KiB L2 / 8 MiB LLC — small enough to be safe
//! on any x86/ARM server of the last decade: undersized tiles cost a few
//! percent, oversized tiles thrash). The result is detected once and cached
//! for the process.

use std::sync::OnceLock;

/// Per-core data-cache sizes in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheTopo {
    /// L1 data cache (per core).
    pub l1d: usize,
    /// L2 (per core or per cluster — sysfs reports what the core sees).
    pub l2: usize,
    /// Last-level cache (L3 when present, else the L2 figure).
    pub llc: usize,
}

/// Safe fallback when detection is unavailable (non-Linux, masked sysfs).
pub const FALLBACK: CacheTopo = CacheTopo {
    l1d: 32 * 1024,
    l2: 512 * 1024,
    llc: 8 * 1024 * 1024,
};

/// The host topology, detected once per process.
pub fn get() -> CacheTopo {
    static TOPO: OnceLock<CacheTopo> = OnceLock::new();
    *TOPO.get_or_init(detect)
}

/// Fresh detection (uncached — prefer [`get`]).
pub fn detect() -> CacheTopo {
    detect_sysfs().unwrap_or(FALLBACK)
}

fn detect_sysfs() -> Option<CacheTopo> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    let mut l1d = None;
    let mut l2 = None;
    let mut l3 = None;
    for idx in 0..=4u32 {
        let dir = base.join(format!("index{idx}"));
        let read = |leaf: &str| -> Option<String> {
            std::fs::read_to_string(dir.join(leaf))
                .ok()
                .map(|s| s.trim().to_string())
        };
        let (Some(level), Some(kind), Some(size)) = (read("level"), read("type"), read("size"))
        else {
            continue;
        };
        let Some(bytes) = parse_size(&size) else { continue };
        match (level.as_str(), kind.as_str()) {
            ("1", "Data") | ("1", "Unified") => l1d = Some(bytes),
            ("2", _) => l2 = Some(bytes),
            ("3", _) => l3 = Some(bytes),
            _ => {}
        }
    }
    // Partial reads still beat the blanket fallback: fill holes per level.
    if l1d.is_none() && l2.is_none() && l3.is_none() {
        return None;
    }
    let l1d = l1d.unwrap_or(FALLBACK.l1d);
    let l2 = l2.unwrap_or(FALLBACK.l2);
    Some(CacheTopo { l1d, l2, llc: l3.unwrap_or(l2) })
}

/// Parse sysfs cache sizes: `"48K"`, `"1280K"`, `"30M"`, bare bytes.
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        b'G' | b'g' => (&s[..s.len() - 1], 1024 * 1024 * 1024),
        _ => (s, 1),
    };
    let v: usize = digits.trim().parse().ok()?;
    (v > 0).then_some(v * mult)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_size_units() {
        assert_eq!(parse_size("48K"), Some(48 * 1024));
        assert_eq!(parse_size("1280K"), Some(1280 * 1024));
        assert_eq!(parse_size("30M"), Some(30 * 1024 * 1024));
        assert_eq!(parse_size("4096"), Some(4096));
        assert_eq!(parse_size(""), None);
        assert_eq!(parse_size("0K"), None);
        assert_eq!(parse_size("xK"), None);
    }

    #[test]
    fn detection_is_sane() {
        let t = get();
        // Whatever the host, the hierarchy must be positive and ordered.
        assert!(t.l1d >= 8 * 1024, "{t:?}");
        assert!(t.l2 >= t.l1d, "{t:?}");
        assert!(t.llc >= t.l2, "{t:?}");
        // And stable across calls (OnceLock).
        assert_eq!(t, get());
    }
}
