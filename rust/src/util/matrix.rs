//! Row-major `f32` matrix with cache-line-aligned storage.
//!
//! Alignment matters twice in this codebase: (1) the solvers' unrolled inner
//! loops auto-vectorize best on 64-byte-aligned rows, and (2) the paper's
//! false-sharing analysis (§5.2.4) assumes "the data is memory aligned" so
//! that threads touching adjacent row blocks never share a cache line.

use std::alloc::{alloc_zeroed, dealloc, handle_alloc_error, Layout};
use std::ops::{Deref, DerefMut};

/// Cache-line size we align to (paper §5.2.4 assumes 64 B lines).
pub const CACHE_LINE: usize = 64;

/// A heap buffer of `f32` aligned to [`CACHE_LINE`].
struct AlignedBuf {
    ptr: *mut f32,
    len: usize,
}

// SAFETY: AlignedBuf is the sole owner of its allocation (no shared
// pointers escape), so moving it to another thread moves exclusive access
// with it; f32 has no thread affinity.
unsafe impl Send for AlignedBuf {}
// SAFETY: shared access only hands out `&[f32]` (mutation requires
// `&mut self`), and f32 is Sync.
unsafe impl Sync for AlignedBuf {}

impl AlignedBuf {
    /// Allocation layout for `len` f32s; panics (rather than wrapping) if
    /// the byte size overflows `usize`.
    fn layout(len: usize) -> Layout {
        let bytes = len.checked_mul(4).expect("buffer byte size overflows usize");
        Layout::from_size_align(bytes, CACHE_LINE).expect("layout")
    }

    fn new_zeroed(len: usize) -> Self {
        assert!(len > 0, "empty buffer");
        let layout = Self::layout(len);
        // SAFETY: layout has non-zero size (len > 0).
        let ptr = unsafe { alloc_zeroed(layout) } as *mut f32;
        if ptr.is_null() {
            handle_alloc_error(layout);
        }
        debug_assert_eq!(ptr as usize % CACHE_LINE, 0, "allocator broke the alignment request");
        Self { ptr, len }
    }
}

impl Drop for AlignedBuf {
    fn drop(&mut self) {
        let layout = Self::layout(self.len);
        // SAFETY: ptr was allocated with exactly this layout.
        unsafe { dealloc(self.ptr as *mut u8, layout) };
    }
}

impl Deref for AlignedBuf {
    type Target = [f32];
    fn deref(&self) -> &[f32] {
        // SAFETY: ptr valid for len f32s for the lifetime of self.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl DerefMut for AlignedBuf {
    fn deref_mut(&mut self) -> &mut [f32] {
        // SAFETY: exclusive access through &mut self.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

/// Row-major `m × n` matrix of `f32` with 64-byte-aligned storage.
pub struct Matrix {
    buf: AlignedBuf,
    m: usize,
    n: usize,
}

impl Matrix {
    /// Zero-filled `m × n` matrix.
    pub fn zeros(m: usize, n: usize) -> Self {
        assert!(m > 0 && n > 0, "matrix dims must be positive ({m}x{n})");
        let len = m.checked_mul(n).unwrap_or_else(|| panic!("matrix size overflows ({m}x{n})"));
        Self { buf: AlignedBuf::new_zeroed(len), m, n }
    }

    /// Matrix from a row-major slice.
    pub fn from_slice(m: usize, n: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), m * n, "data length != m*n");
        let mut out = Self::zeros(m, n);
        out.buf.copy_from_slice(data);
        out
    }

    /// Matrix filled by `f(i, j)`.
    pub fn from_fn(m: usize, n: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut out = Self::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                out.buf[i * n + j] = f(i, j);
            }
        }
        out
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.m
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.m * self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false // dims are validated positive at construction
    }

    /// Immutable view of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.buf[i * self.n..(i + 1) * self.n]
    }

    /// Mutable view of row `i`.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.buf[i * self.n..(i + 1) * self.n]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.buf[i * self.n + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.buf[i * self.n + j] = v;
    }

    /// Whole storage, row-major.
    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Whole storage, row-major, mutable.
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.buf
    }

    /// Column sums into a caller-provided buffer (one row-major sweep,
    /// no allocation — the solver-session warmup contract relies on this).
    pub fn col_sums_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.n, "col_sums_into length mismatch");
        out.fill(0.0);
        for i in 0..self.m {
            for (acc, &v) in out.iter_mut().zip(self.row(i)) {
                *acc += v;
            }
        }
    }

    /// Column sums (one row-major sweep).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n];
        self.col_sums_into(&mut out);
        out
    }

    /// Row sums.
    pub fn row_sums(&self) -> Vec<f32> {
        (0..self.m).map(|i| self.row(i).iter().sum()).collect()
    }

    /// Max absolute element-wise difference against `other`.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!((self.m, self.n), (other.m, other.n), "shape mismatch");
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    }

    /// Max relative difference (denominator clamped at `atol`).
    pub fn max_rel_diff(&self, other: &Matrix, atol: f32) -> f32 {
        assert_eq!((self.m, self.n), (other.m, other.n), "shape mismatch");
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| (a - b).abs() / a.abs().max(atol))
            .fold(0f32, f32::max)
    }
}

impl Clone for Matrix {
    fn clone(&self) -> Self {
        Self::from_slice(self.m, self.n, self.as_slice())
    }
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.m, self.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alignment_is_cache_line() {
        for n in [1, 3, 17, 1024] {
            let m = Matrix::zeros(3, n);
            assert_eq!(m.as_slice().as_ptr() as usize % CACHE_LINE, 0);
        }
    }

    #[test]
    fn row_access_and_sums() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 4 + j) as f32);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(m.row_sums(), vec![6.0, 22.0, 38.0]);
        assert_eq!(m.col_sums(), vec![12.0, 15.0, 18.0, 21.0]);
    }

    #[test]
    fn from_slice_roundtrip() {
        let data: Vec<f32> = (0..12).map(|x| x as f32).collect();
        let m = Matrix::from_slice(3, 4, &data);
        assert_eq!(m.as_slice(), &data[..]);
        let c = m.clone();
        assert_eq!(c.max_abs_diff(&m), 0.0);
    }

    #[test]
    #[should_panic(expected = "dims must be positive")]
    fn zero_dims_panic() {
        let _ = Matrix::zeros(0, 4);
    }

    /// Regression: `m * n` (and the byte size below it) used to be computed
    /// with wrapping arithmetic, so adversarial dims could wrap to a tiny
    /// allocation before `Layout` ever saw the size. Both products are now
    /// checked and must panic, not wrap.
    #[test]
    #[should_panic(expected = "overflows")]
    fn oversized_dims_panic_not_wrap() {
        let _ = Matrix::zeros(usize::MAX / 2, 3);
    }

    #[test]
    fn diff_metrics() {
        let a = Matrix::from_slice(1, 3, &[1.0, 2.0, 4.0]);
        let b = Matrix::from_slice(1, 3, &[1.0, 2.5, 4.0]);
        assert!((a.max_abs_diff(&b) - 0.5).abs() < 1e-7);
        assert!((a.max_rel_diff(&b, 1e-9) - 0.25).abs() < 1e-6);
    }
}
