//! Deterministic xorshift64* RNG.
//!
//! No `rand` crate is available offline; benchmarks and workload generators
//! only need a fast, seedable, reproducible stream, which xorshift64*
//! provides (period 2^64 − 1, passes BigCrush for our purposes).

/// xorshift64* generator.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Seeded generator; a zero seed is mapped to a fixed non-zero constant
    /// (xorshift's all-zero state is absorbing).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform `f32` in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        // Top 24 bits -> [0, 1) with full f32 mantissa coverage.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform `f32` in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, bound)`.
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        (self.next_u64() % bound as u64) as usize
    }

    /// Standard normal via Box–Muller (used by the point-cloud generators).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.next_f32().max(1e-7);
        let u2 = self.next_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of uniforms in `[lo, hi)`.
    pub fn uniform_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.uniform(lo, hi)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = XorShift::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = XorShift::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = XorShift::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn zero_seed_not_absorbing() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn f32_range_and_mean() {
        let mut r = XorShift::new(1);
        let xs: Vec<f32> = (0..10_000).map(|_| r.next_f32()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = XorShift::new(2);
        let xs: Vec<f32> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn below_in_bounds() {
        let mut r = XorShift::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
