//! Small statistics helpers used by the bench harness and metrics.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

/// Geometric mean (the paper's "average speedup" aggregation); 0 for empty.
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(f64::MIN_POSITIVE).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (nearest-rank, p in [0, 100]) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Median (50th percentile).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median() {
        let xs = [1.0, 2.0, 3.0, 4.0, 100.0];
        assert_eq!(mean(&xs), 22.0);
        assert_eq!(median(&xs), 3.0);
    }

    #[test]
    fn geomean_of_speedups() {
        let xs = [2.0, 8.0];
        assert!((geomean(&xs) - 4.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&xs, 50.0) - 50.0).abs() <= 1.0);
    }

    #[test]
    fn stddev_constant_is_zero() {
        assert_eq!(stddev(&[5.0, 5.0, 5.0]), 0.0);
    }
}
