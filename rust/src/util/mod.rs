//! Shared low-level utilities: aligned matrix storage, RNG, stats, timing.

pub mod matrix;
pub mod rng;
pub mod stats;
pub mod timer;

pub use matrix::Matrix;
pub use rng::XorShift;
pub use timer::Timer;
