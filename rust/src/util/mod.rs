//! Shared low-level utilities: aligned matrix storage, cache-topology
//! detection, RNG, lane-reduction helpers, stats, timing, telemetry.

pub mod cputopo;
pub mod matrix;
pub mod rng;
pub mod simd;
pub mod stats;
pub mod telemetry;
pub mod timer;

pub use matrix::Matrix;
pub use rng::XorShift;
pub use timer::Timer;
