//! Wall-clock timing helpers for benches and service metrics.
//!
//! Built on [`super::telemetry::now_ns`], the crate's single monotonic
//! clock source — bench timings and span-trace timestamps share one
//! anchor, so a `Timer` reading can be compared directly against
//! exported trace events.

use std::time::Duration;

use super::telemetry::now_ns;

/// A simple start/elapsed timer on the shared telemetry clock.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start_ns: u64,
}

impl Timer {
    pub fn start() -> Self {
        Self { start_ns: now_ns() }
    }

    pub fn elapsed(&self) -> Duration {
        Duration::from_nanos(now_ns().saturating_sub(self.start_ns))
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    /// The shared-clock reading this timer started at (the value a span
    /// recorded over the same region would carry as `start_ns`).
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }
}

/// Times `f()`, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Runs `f` `reps` times after `warmup` unmeasured runs; returns the
/// per-rep seconds (minimum is the usual bench statistic; the harness
/// decides the aggregation).
pub fn sample<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..reps)
        .map(|_| {
            let t = Timer::start();
            std::hint::black_box(f());
            t.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let (v, s) = time_it(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(s >= 0.004, "s={s}");
    }

    #[test]
    fn sample_counts() {
        let mut calls = 0;
        let xs = sample(2, 3, || calls += 1);
        assert_eq!(xs.len(), 3);
        assert_eq!(calls, 5);
    }
}
