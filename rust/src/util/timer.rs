//! Wall-clock timing helpers for benches and service metrics.

use std::time::{Duration, Instant};

/// A simple start/elapsed timer.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }
}

/// Times `f()`, returning `(result, seconds)`.
pub fn time_it<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Runs `f` `reps` times after `warmup` unmeasured runs; returns the
/// per-rep seconds (minimum is the usual bench statistic; the harness
/// decides the aggregation).
pub fn sample<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Vec<f64> {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    (0..reps)
        .map(|_| {
            let t = Timer::start();
            std::hint::black_box(f());
            t.elapsed().as_secs_f64()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_measures() {
        let (v, s) = time_it(|| {
            std::thread::sleep(Duration::from_millis(5));
            42
        });
        assert_eq!(v, 42);
        assert!(s >= 0.004, "s={s}");
    }

    #[test]
    fn sample_counts() {
        let mut calls = 0;
        let xs = sample(2, 3, || calls += 1);
        assert_eq!(xs.len(), 3);
        assert_eq!(calls, 5);
    }
}
