//! Shared multi-lane reduction helpers for the portable (auto-vectorized)
//! kernel paths.
//!
//! Every unrolled inner loop in the solver kernels uses the same trick: 16
//! independent accumulator lanes, wide enough for AVX2/AVX-512
//! auto-vectorization AND to break the add-latency dependency chain (4
//! lanes capped the fused primitive at ~47% of streaming peak — see
//! EXPERIMENTS.md §Perf). Before this module, the lane fold and the plain
//! wide sum were copy-pasted between `algo::mapuot` and `algo::pot`; both
//! now funnel through here so the lane width and fold order stay uniform
//! (the fold is a *sequential* sum over the lanes — changing it to a tree
//! would change results bit-for-bit and break the pool/scope bit-match
//! contract).

/// Accumulator lanes used by the unrolled kernel loops.
pub const LANES: usize = 16;

/// Fold the lane accumulators into one scalar (sequential order — part of
/// the bit-exactness contract, see module docs).
#[inline]
pub fn fold(acc: &[f32; LANES]) -> f32 {
    acc.iter().sum::<f32>()
}

/// Vectorizable 16-lane sum of a slice (NumPy's pairwise-sum ufunc is
/// similarly vectorized, so the POT baseline uses this to stay honest).
#[inline]
pub fn wide_sum(xs: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let chunks = xs.len() / LANES;
    let (h, t) = xs.split_at(chunks * LANES);
    for w in h.chunks_exact(LANES) {
        for k in 0..LANES {
            acc[k] += w[k];
        }
    }
    fold(&acc) + t.iter().sum::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_sum_matches_serial_sum() {
        let mut rng = crate::util::XorShift::new(7);
        for n in [0usize, 1, 15, 16, 17, 33, 257, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
            let serial: f32 = xs.iter().sum();
            let wide = wide_sum(&xs);
            assert!((wide - serial).abs() <= 1e-4 * serial.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn fold_is_sequential() {
        let mut acc = [0f32; LANES];
        for (k, a) in acc.iter_mut().enumerate() {
            *a = k as f32;
        }
        assert_eq!(fold(&acc), (0..LANES).sum::<usize>() as f32);
    }
}
