//! Shared multi-lane reduction helpers for the portable (auto-vectorized)
//! kernel paths.
//!
//! Every unrolled inner loop in the solver kernels uses the same trick: 16
//! independent accumulator lanes, wide enough for AVX2/AVX-512
//! auto-vectorization AND to break the add-latency dependency chain (4
//! lanes capped the fused primitive at ~47% of streaming peak — see
//! EXPERIMENTS.md §Perf). Before this module, the lane fold and the plain
//! wide sum were copy-pasted between `algo::mapuot` and `algo::pot`; both
//! now funnel through here so the lane width and fold order stay uniform
//! (the fold is a *sequential* sum over the lanes — changing it to a tree
//! would change results bit-for-bit and break the pool/scope bit-match
//! contract).

/// Accumulator lanes used by the unrolled kernel loops.
pub const LANES: usize = 16;

// ---------------------------------------------------------------------------
// Fast exponential (the matfree generation primitive's core)
// ---------------------------------------------------------------------------
//
// `f32::exp` is a libm call, which LLVM cannot vectorize — and the
// materialization-free backend evaluates exp once per plan cell per
// iteration, so a scalar call chain would make kernel *generation* the
// bottleneck instead of memory traffic. `fast_exp` is the classic
// branch-free range-reduction scheme (Cody–Waite split of ln 2, a
// degree-5 minimax polynomial for `exp(r)` on `[-ln2/2, ln2/2]`, exponent
// reconstruction through the f32 bit layout), accurate to ~2 ulp — well
// inside the 1e-6 relative agreement contract the kernel property tests
// pin (`rust/tests/prop_kernels.rs::fast_exp_matches_libm_reference`).
//
// The same constants drive three implementations: this scalar form (the
// `Unrolled` kernel backend calls it in 16-lane chunks, which LLVM
// auto-vectorizes — every operation is plain ALU/bit math), the
// hand-written AVX2 `exp_ps` in `algo::kernels`, and nothing else — the
// `Scalar` kernel backend keeps `f32::exp` as the libm reference the
// others are tested against.

/// High bits of ln 2 (Cody–Waite split: exactly representable, so
/// `x - n·LN2_HI` is exact for the `n` range in use).
pub(crate) const EXP_LN2_HI: f32 = 0.693_359_375;
/// Low bits of ln 2 (`LN2_HI + LN2_LO = ln 2` to f64 accuracy).
pub(crate) const EXP_LN2_LO: f32 = -2.121_944_4e-4;
/// Degree-5 minimax coefficients for `exp(r) - 1 - r` on the reduced
/// range, highest power first (Cephes `expf`; the trailing 1/2 term is
/// exactly representable, so it is written as such).
pub(crate) const EXP_POLY: [f32; 6] = [
    1.987_569_2e-4,
    1.398_2e-3,
    8.333_452e-3,
    4.166_579_6e-2,
    1.666_666_5e-1,
    0.5,
];
/// Inputs below this produce 0 even after gradual underflow
/// (`exp(-104) < ` half the smallest positive subnormal).
pub(crate) const EXP_LO_CLAMP: f32 = -104.0;
/// Inputs above this overflow to infinity (`ln(f32::MAX) ≈ 88.72`).
pub(crate) const EXP_HI_CLAMP: f32 = 89.0;

/// Branch-free `e^x` for f32, ~2 ulp, with IEEE-consistent edges:
/// overflow saturates to `+inf`, underflow passes through gradual
/// (subnormal) rounding to 0, and NaN stays NaN. Auto-vectorizable (no
/// calls, no data-dependent branches).
#[inline]
pub fn fast_exp(x: f32) -> f32 {
    // The clamps keep the exponent arithmetic in range; both saturations
    // land on the mathematically correct result (0 / +inf) through the
    // reconstruction below, so no separate special-case branch exists.
    let x = x.clamp(EXP_LO_CLAMP, EXP_HI_CLAMP);
    let n = (x * std::f32::consts::LOG2_E).round();
    let r = (x - n * EXP_LN2_HI) - n * EXP_LN2_LO;
    let mut p = EXP_POLY[0];
    p = p * r + EXP_POLY[1];
    p = p * r + EXP_POLY[2];
    p = p * r + EXP_POLY[3];
    p = p * r + EXP_POLY[4];
    p = p * r + EXP_POLY[5];
    let e = (p * r * r + r) + 1.0;
    // 2^n in two factors so the subnormal range rounds gradually (a single
    // `(n + 127) << 23` would need n >= -126) and n = 128 still overflows
    // cleanly to +inf. n in [-151, 129] ⇒ both halves in [-76, 65], whose
    // biased exponents are valid normal-f32 bit patterns.
    let n = n as i32; // NaN input ⇒ n = 0 ⇒ e (NaN) passes through
    let half = n >> 1;
    let a = f32::from_bits(((half + 127) as u32) << 23);
    let b = f32::from_bits(((n - half + 127) as u32) << 23);
    a * (b * e)
}

/// Fold the lane accumulators into one scalar (sequential order — part of
/// the bit-exactness contract, see module docs).
#[inline]
pub fn fold(acc: &[f32; LANES]) -> f32 {
    acc.iter().sum::<f32>()
}

/// Vectorizable 16-lane sum of a slice (NumPy's pairwise-sum ufunc is
/// similarly vectorized, so the POT baseline uses this to stay honest).
#[inline]
pub fn wide_sum(xs: &[f32]) -> f32 {
    let mut acc = [0f32; LANES];
    let chunks = xs.len() / LANES;
    let (h, t) = xs.split_at(chunks * LANES);
    for w in h.chunks_exact(LANES) {
        for k in 0..LANES {
            acc[k] += w[k];
        }
    }
    fold(&acc) + t.iter().sum::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wide_sum_matches_serial_sum() {
        let mut rng = crate::util::XorShift::new(7);
        for n in [0usize, 1, 15, 16, 17, 33, 257, 1000] {
            let xs: Vec<f32> = (0..n).map(|_| rng.uniform(0.0, 1.0)).collect();
            let serial: f32 = xs.iter().sum();
            let wide = wide_sum(&xs);
            assert!((wide - serial).abs() <= 1e-4 * serial.abs().max(1.0), "n={n}");
        }
    }

    #[test]
    fn fold_is_sequential() {
        let mut acc = [0f32; LANES];
        for (k, a) in acc.iter_mut().enumerate() {
            *a = k as f32;
        }
        assert_eq!(fold(&acc), (0..LANES).sum::<usize>() as f32);
    }

    /// Agreement with libm across the magnitude ladder, including the
    /// subnormal-result range: relative tolerance 1e-6, with the
    /// denominator clamped at the smallest normal so the gradual-underflow
    /// tail is held to an equivalent absolute bound (deep subnormals have
    /// no 1e-6-relative neighbors — their ulp spacing is percent-scale).
    #[test]
    fn fast_exp_tracks_libm() {
        let mut rng = crate::util::XorShift::new(3);
        let mut xs: Vec<f32> = vec![0.0, -0.0, 1.0, -1.0];
        // Magnitude sweep: 1e-6 .. ~1e2, both signs (positive capped under
        // the overflow cutoff), plus the underflow/subnormal band.
        for decade in -6..=2 {
            for _ in 0..64 {
                let mag = 10f32.powi(decade) * rng.uniform(1.0, 10.0);
                xs.push(-mag);
                if mag < 80.0 {
                    xs.push(mag);
                }
            }
        }
        for sub in [-87.0, -88.0, -95.0, -100.0, -103.0, -103.9] {
            xs.push(sub);
        }
        for x in xs {
            let got = fast_exp(x);
            let want = x.exp();
            assert!(
                (got - want).abs() <= 1e-6 * want.abs().max(f32::MIN_POSITIVE),
                "fast_exp({x}) = {got:e}, libm {want:e}"
            );
        }
    }

    /// IEEE-consistent edges: overflow saturates to +inf, deep underflow
    /// reaches exactly 0 (no negative-zero, no garbage exponent), and NaN
    /// propagates.
    #[test]
    fn fast_exp_edges() {
        assert_eq!(fast_exp(0.0), 1.0);
        assert_eq!(fast_exp(f32::NEG_INFINITY), 0.0);
        assert_eq!(fast_exp(-120.0), 0.0);
        assert_eq!(fast_exp(f32::INFINITY), f32::INFINITY);
        assert_eq!(fast_exp(100.0), f32::INFINITY);
        assert!(fast_exp(f32::NAN).is_nan());
        // The subnormal band is gradual, not flushed: somewhere below the
        // smallest normal the result is still positive.
        let sub = fast_exp(-90.0);
        assert!(sub > 0.0 && sub < f32::MIN_POSITIVE, "{sub:e}");
    }
}
