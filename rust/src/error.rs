//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the MAP-UOT library.
#[derive(Debug, Error)]
pub enum Error {
    /// Problem construction or solver-input validation failed.
    #[error("invalid problem: {0}")]
    InvalidProblem(String),

    /// Configuration file / preset errors.
    #[error("config error: {0}")]
    Config(String),

    /// AOT artifact manifest / loading errors.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT runtime failures (compile, execute, literal conversion).
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Coordinator/service lifecycle errors (queue closed, worker died...).
    #[error("service error: {0}")]
    Service(String),

    /// Solver did not converge within the iteration budget.
    #[error("no convergence after {iters} iterations (err={err})")]
    NoConvergence { iters: usize, err: f32 },

    /// A `ConvergenceObserver` canceled the solve at a check boundary.
    #[error("solve canceled by observer after {iters} iterations")]
    Canceled { iters: usize },

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
