//! Crate-wide error type.
//!
//! Display/Error impls are hand-rolled (no `thiserror`): the build
//! environment is offline, and the crate's no-external-deps contract
//! (see `rust/Cargo.toml`) is what keeps the tier-1 gate runnable there.

/// Errors surfaced by the MAP-UOT library.
#[derive(Debug)]
pub enum Error {
    /// Problem construction or solver-input validation failed.
    InvalidProblem(String),

    /// Configuration file / preset errors.
    Config(String),

    /// AOT artifact manifest / loading errors.
    Artifact(String),

    /// PJRT runtime failures (compile, execute, literal conversion).
    Runtime(String),

    /// Coordinator/service lifecycle errors (queue closed, worker died...).
    Service(String),

    /// Solver did not converge within the iteration budget.
    NoConvergence { iters: usize, err: f32 },

    /// A `ConvergenceObserver` canceled the solve at a check boundary.
    Canceled { iters: usize },

    /// Underlying I/O failure (artifact files, config files).
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidProblem(msg) => write!(f, "invalid problem: {msg}"),
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Artifact(msg) => write!(f, "artifact error: {msg}"),
            Error::Runtime(msg) => write!(f, "runtime error: {msg}"),
            Error::Service(msg) => write!(f, "service error: {msg}"),
            Error::NoConvergence { iters, err } => {
                write!(f, "no convergence after {iters} iterations (err={err})")
            }
            Error::Canceled { iters } => {
                write!(f, "solve canceled by observer after {iters} iterations")
            }
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::xla_stub::Error> for Error {
    fn from(e: crate::xla_stub::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
