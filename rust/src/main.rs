//! `map-uot` CLI — leader entrypoint for the solver service and the
//! reproduction harnesses.
//!
//! Subcommands:
//!   solve    one UOT solve (native or PJRT), print the report
//!   serve    run the coordinator under a synthetic request load
//!   app      run one of the paper's four applications
//!   fig      regenerate one paper figure (2..17) or `all`
//!   info     platform + artifact inventory

#![deny(unsafe_op_in_unsafe_fn)]

use std::collections::HashMap;

use map_uot::algo::{
    AffinityHint, CheckEvent, CostKind, GeomProblem, KernelKind, ObserverAction, ParallelBackend,
    Problem, SolverKind, SolverSession, SparseProblem, StopRule, TileSpec,
};
use map_uot::apps;
use map_uot::bench::figures;
use map_uot::config::{Backend, OnedMode, ServiceConfig};
use map_uot::coordinator::{self, Service};
use map_uot::error::Result;
use map_uot::runtime::Runtime;
use map_uot::util::telemetry::{self, Roofline};
use map_uot::util::Timer;

/// Tiny flag parser: `--key value` pairs after the subcommand.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            if let Some(key) = argv[i].strip_prefix("--") {
                // A following `--token` is the next flag, not this flag's
                // value — bare switches like `--progress` read as "true".
                match argv.get(i + 1) {
                    Some(v) if !v.starts_with("--") => {
                        flags.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        flags.insert(key.to_string(), "true".into());
                        i += 1;
                    }
                }
            } else {
                i += 1;
            }
        }
        Self { flags }
    }

    fn get<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.into())
    }
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(String::as_str).unwrap_or("help");
    let args = Args::parse(&argv[1.min(argv.len())..]);
    let code = match cmd {
        "solve" => cmd_solve(&args),
        "serve" => cmd_serve(&args),
        "stats" => cmd_stats(&args),
        "app" => cmd_app(&argv.get(1).map(String::as_str).unwrap_or(""), &args),
        "fig" => cmd_fig(&argv.get(1).map(String::as_str).unwrap_or("all")),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            0
        }
    };
    std::process::exit(code);
}

fn print_help() {
    println!(
        "map-uot — memory-efficient unbalanced optimal transport (paper reproduction)\n\
         \n\
         USAGE: map-uot <command> [--flag value ...]\n\
         \n\
         COMMANDS\n\
         \x20 solve  --m 1024 --n 1024 --fi 0.7 --solver mapuot|coffee|pot\n\
         \x20        --threads 1 --max-iter 1000 --tol 1e-4 --seed 42 --backend native|pjrt\n\
         \x20        --par pool|spawn (threaded engine: persistent worker pool, default,\n\
         \x20        or legacy scope-per-iteration) --pin (pin pool workers to cores)\n\
         \x20        --kernel auto|scalar|unrolled|avx2 (SIMD backend; auto = runtime\n\
         \x20        CPUID dispatch) --tile auto|off|tune|<cols> (cache-aware column\n\
         \x20        tiling of the fused sweep)\n\
         \x20        --sparse <threshold> (drop plan entries <= threshold and solve on\n\
         \x20        the fused CSR backend; MAP-UOT only)\n\
         \x20        --matfree <epsilon> (solve a synthetic geometric problem on the\n\
         \x20        materialization-free scaling-form backend — O(m+n) state, the plan\n\
         \x20        is never stored; MAP-UOT only) --dim <d> (point dimension, default 3)\n\
         \x20        --cost sqeuclid|euclid (ground cost; the kernel is exp(-cost/eps))\n\
         \x20        --warm <cap>|off (warm-start cache: seed repeated solves from up to\n\
         \x20        <cap> cached converged scalings; default off)\n\
         \x20        --ti (translation-invariant sweeps — pre-sweep global-mass\n\
         \x20        correction; MAP-UOT only)\n\
         \x20        --eps-schedule <from>:<steps> (matfree only: geometric coarse-to-fine\n\
         \x20        bandwidth ladder from <from> down to the problem epsilon)\n\
         \x20        --oned auto|on|off (matfree only: route 1D Euclidean geometries to\n\
         \x20        the exact near-linear sweep; auto falls back to matfree when\n\
         \x20        ineligible, on makes ineligibility an error; default auto)\n\
         \x20        --progress (print per-check convergence telemetry)\n\
         \x20        --trace <path> (record phase spans and export them on exit:\n\
         \x20        .jsonl = one event per line, else chrome://tracing JSON; also\n\
         \x20        prints the analytic roofline line for the solve)\n\
         \x20 serve  --requests 64 --workers 4 --size 256 --backend native|pjrt\n\
         \x20        --trace <path> (span trace across the worker pool, exported at\n\
         \x20        shutdown)\n\
         \x20 stats  --requests 16 --workers 2 --size 128 (run an in-process demo\n\
         \x20        load and print the versioned metrics JSON; --trace <path> also\n\
         \x20        exports the span trace) | --check-trace <path> (validate a\n\
         \x20        previously exported trace file and exit)\n\
         \x20 app    color|domain|bayes|filter|entropic2d|wmd  [--solver mapuot]\n\
         \x20 fig    2|3|4|5|8|9|10|11|12|13|14|15|16|17|all\n\
         \x20 info   [--artifacts artifacts]"
    );
}

fn cmd_solve(a: &Args) -> i32 {
    let m = a.get("m", 1024usize);
    let n = a.get("n", 1024usize);
    let fi = a.get("fi", 0.7f32);
    let solver = SolverKind::parse(&a.str("solver", "mapuot")).unwrap_or(SolverKind::MapUot);
    // The dense problem is built lazily per branch: a --matfree run at a
    // dense-impossible shape must never allocate the M·N plan at all.
    let seed = a.get("seed", 42u64);
    let stop = StopRule {
        tol: a.get("tol", 1e-4f32),
        delta_tol: a.get("delta-tol", 1e-6f32),
        max_iter: a.get("max-iter", 1000usize),
    };

    // The matfree-only flags are rejected loudly when they cannot apply —
    // same contract as --par/--kernel: nothing silently measures the
    // wrong backend.
    if !a.flags.contains_key("matfree") && (a.flags.contains_key("dim") || a.flags.contains_key("cost")) {
        eprintln!(
            "error: --dim/--cost describe the point clouds of a matfree solve and require \
             --matfree <epsilon>"
        );
        return 1;
    }
    if a.flags.contains_key("matfree") && a.str("backend", "native") == "pjrt" {
        eprintln!("error: --matfree runs on the native backend only (PJRT executes dense artifacts)");
        return 1;
    }

    // The iteration-count accelerators live in the native session layer, so
    // they fail loudly on the PJRT path instead of silently not applying.
    let warm = match a.flags.get("warm") {
        None => 0usize,
        Some(raw) => match raw.to_ascii_lowercase().as_str() {
            "off" | "none" => 0,
            s => match s.parse::<usize>() {
                Ok(cap) => cap,
                Err(_) => {
                    eprintln!("error: --warm expects an entry count or off, got {raw:?}");
                    return 1;
                }
            },
        },
    };
    let ti = a.get("ti", false);
    if ti && solver != SolverKind::MapUot {
        eprintln!("error: --ti corrects the MAP-UOT sweep (use --solver mapuot)");
        return 1;
    }
    let eps_schedule = match a.flags.get("eps-schedule") {
        None => None,
        Some(raw) => {
            if !a.flags.contains_key("matfree") {
                eprintln!(
                    "error: --eps-schedule schedules the matfree kernel bandwidth and \
                     requires --matfree <epsilon>"
                );
                return 1;
            }
            let parsed = raw.split_once(':').and_then(|(f, s)| {
                Some((f.parse::<f32>().ok()?, s.parse::<usize>().ok()?))
            });
            match parsed {
                Some((from, steps)) if from.is_finite() && from > 0.0 && steps >= 1 => {
                    Some((from, steps))
                }
                _ => {
                    eprintln!(
                        "error: --eps-schedule expects <from>:<steps> with a finite \
                         bandwidth > 0 and steps >= 1, got {raw:?}"
                    );
                    return 1;
                }
            }
        }
    };
    // The 1D fast-path selector rides on the geometric (matfree) path and
    // conflicts with the ε ladder when hard-required — same loud contract.
    let oned = match a.flags.get("oned") {
        None => OnedMode::Auto,
        Some(raw) => {
            if !a.flags.contains_key("matfree") {
                eprintln!(
                    "error: --oned routes geometric solves and requires --matfree <epsilon>"
                );
                return 1;
            }
            match OnedMode::parse(raw) {
                Some(mode) => mode,
                None => {
                    eprintln!("error: --oned expects auto|on|off, got {raw:?}");
                    return 1;
                }
            }
        }
    };
    if oned == OnedMode::On && eps_schedule.is_some() {
        eprintln!(
            "error: --oned on and --eps-schedule are mutually exclusive (the ladder \
             amortizes matfree sweeps; the exact 1D path has none)"
        );
        return 1;
    }
    if a.str("backend", "native") == "pjrt" && (warm > 0 || ti) {
        eprintln!("error: --warm/--ti apply to the native session layer, not --backend pjrt");
        return 1;
    }
    // Span tracing + the analytic roofline report ride on every solve
    // path; the export format is picked from the extension (.jsonl =
    // line-delimited events, anything else chrome://tracing JSON).
    let trace = a.flags.get("trace").cloned();

    if a.str("backend", "native") == "pjrt" {
        return run_or_die(|| {
            let cfg = ServiceConfig {
                backend: Backend::Pjrt,
                stop,
                artifacts_dir: a.str("artifacts", "artifacts"),
                trace: trace.clone(),
                ..ServiceConfig::default()
            };
            let svc = Service::start(cfg)?;
            let solved = svc.solve_blocking(Problem::random(m, n, fi, seed))?;
            println!(
                "pjrt solve {m}x{n}: iters={} err={:.3e} converged={} latency={:.1}ms",
                solved.report.iters,
                solved.report.err,
                solved.report.converged,
                solved.latency_s * 1e3
            );
            if trace.is_some() {
                let roof = Roofline::materialized(
                    (m * n) as u64,
                    solver.passes_per_iter() as u64,
                    solver.accesses_per_element() as u64,
                    4,
                    solved.report.iters as u64,
                );
                println!("{}", roof.cli_line(solved.latency_s));
            }
            // The service exports the span trace itself at shutdown.
            svc.shutdown();
            Ok(())
        });
    }

    // Unlike --solver, a typo here must not silently fall back: the flag
    // exists to benchmark the two backends head-to-head.
    let par = match ParallelBackend::parse(&a.str("par", "pool")) {
        Some(par) => par,
        None => {
            eprintln!("error: unknown --par backend {:?} (expected pool|spawn)", a.str("par", ""));
            return 1;
        }
    };
    // Same contract for the kernel/tiling knobs: these exist to pin down
    // what exactly is being measured, so typos must fail loudly.
    let kernel = match KernelKind::parse(&a.str("kernel", "auto")) {
        Some(k) => k,
        None => {
            eprintln!(
                "error: unknown --kernel backend {:?} (expected auto|scalar|unrolled|avx2)",
                a.str("kernel", "")
            );
            return 1;
        }
    };
    let tile = match TileSpec::parse(&a.str("tile", "auto")) {
        Some(t) => t,
        None => {
            eprintln!(
                "error: unknown --tile policy {:?} (expected auto|off|tune|<cols>)",
                a.str("tile", "")
            );
            return 1;
        }
    };
    let affinity = if a.get("pin", false) { AffinityHint::Pinned } else { AffinityHint::None };

    // One builder serves both the dense and the sparse path — the flags
    // they share (threads/par/pin/stop/progress) are wired exactly once.
    let mut builder = SolverSession::builder(solver)
        .threads(a.get("threads", 1usize))
        .backend(par)
        .affinity(affinity)
        .stop(stop)
        .warm(warm)
        .ti(ti);
    if let Some(path) = &trace {
        builder = builder.trace(path.clone());
    }
    // Only reachable with --matfree (rejected above otherwise), so the
    // dense/sparse paths never see a ladder they would refuse.
    if let Some((from, steps)) = eps_schedule {
        builder = builder.eps_schedule(from, steps);
    }
    if a.get("progress", false) {
        builder = builder.observer(|ev: CheckEvent| {
            eprintln!("  iter {:5}  err={:.3e}  delta={:.3e}", ev.iters, ev.err, ev.delta);
            ObserverAction::Continue
        });
    }

    // Matfree path: --matfree <epsilon> solves a synthetic geometric
    // problem (points uniform in the unit cube) on the scaling-form
    // backend — the plan is never materialized. Same loud-failure
    // contract as every other backend selector.
    if let Some(raw) = a.flags.get("matfree") {
        if a.flags.contains_key("sparse") {
            eprintln!("error: --matfree and --sparse select different backends; pick one");
            return 1;
        }
        let epsilon = match raw.parse::<f32>() {
            Ok(e) if e.is_finite() && e > 0.0 => e,
            _ => {
                eprintln!("error: --matfree expects a finite epsilon > 0, got {raw:?}");
                return 1;
            }
        };
        if solver != SolverKind::MapUot {
            eprintln!(
                "error: --matfree runs the scaling-form MAP-UOT sweep (use --solver mapuot)"
            );
            return 1;
        }
        let d = a.get("dim", 3usize);
        if d == 0 {
            eprintln!("error: --dim must be >= 1");
            return 1;
        }
        let cost = match CostKind::parse(&a.str("cost", "sqeuclid")) {
            Some(c) => c,
            None => {
                eprintln!(
                    "error: unknown --cost kind {:?} (expected sqeuclid|euclid)",
                    a.str("cost", "")
                );
                return 1;
            }
        };
        let gp = GeomProblem::random(m, n, d, cost, epsilon, fi, seed);
        // Problem-class routing (--oned): the same classifier the service
        // uses picks between the exact near-linear 1D sweep and the
        // iterative matfree sweep.
        let class = match oned {
            OnedMode::Off => {
                coordinator::ProblemClass::General { reason: "--oned off".into() }
            }
            _ if eps_schedule.is_some() => coordinator::ProblemClass::General {
                reason: "--eps-schedule pins the solve to the matfree path".into(),
            },
            _ => coordinator::classify_geom(&gp, coordinator::ONED_AXIS_TOL),
        };
        match class {
            coordinator::ProblemClass::Oned { axis } => {
                let projected;
                let p1 = if gp.d == 1 {
                    &gp
                } else {
                    match coordinator::project_oned(&gp, axis) {
                        Ok(p) => {
                            projected = p;
                            &projected
                        }
                        Err(e) => {
                            eprintln!("error: {e}");
                            return 1;
                        }
                    }
                };
                let mut session = builder.build_oned(p1);
                let report = match session.solve_oned(p1) {
                    Ok(r) => r,
                    Err(e) => {
                        eprintln!("error: {e}");
                        return 1;
                    }
                };
                let t = session
                    .oned_transport()
                    .expect("solve_oned populates the transport list");
                let state_kb = (24 * (m + n)) as f64 / 1024.0;
                let dense_mb = (m * n * 4) as f64 / (1024.0 * 1024.0);
                println!(
                    "MAP-UOT oned solve {m}x{n} cost={} eps={epsilon} [axis={axis}]: \
                     iters={} err={:.3e} delta={:.3e} converged={} time={:.1}ms | \
                     transport {} entries, created={:.3} destroyed={:.3} | \
                     resident ~{state_kb:.0} KB vs dense plan {dense_mb:.0} MB",
                    cost.name(),
                    report.iters,
                    report.err,
                    report.delta,
                    report.converged,
                    report.seconds * 1e3,
                    t.entries.len(),
                    t.created,
                    t.destroyed,
                );
                let roof = Roofline::oned(m as u64, n as u64, report.iters as u64);
                report_trace(&session, &trace, roof, report.seconds);
                return 0;
            }
            coordinator::ProblemClass::General { reason } => {
                if oned == OnedMode::On {
                    eprintln!("error: --oned on, but the problem is not 1D-eligible: {reason}");
                    return 1;
                }
            }
        }
        // The kernel/tile knobs *do* apply here: they select the exp
        // backend and the generation panel width.
        let mut session = builder.kernel(kernel).tile(tile).build_matfree(&gp);
        let policy = session.policy();
        let report = match session.solve_matfree(&gp) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let threads = a.get("threads", 1usize).max(1);
        let state_kb = ((2 * m + 4 * n + 2 * threads * n) * 4) as f64 / 1024.0;
        let dense_mb = (m * n * 4) as f64 / (1024.0 * 1024.0);
        println!(
            "MAP-UOT matfree solve {m}x{n} d={d} cost={} eps={epsilon} [kernel={} tile={}]: \
             iters={} err={:.3e} delta={:.3e} converged={} time={:.1}ms ({:.2} ms/iter) | \
             resident ~{state_kb:.0} KB vs dense plan {dense_mb:.0} MB",
            cost.name(),
            policy.kind().name(),
            if policy.tile_cols() == 0 { "off".to_string() } else { policy.tile_cols().to_string() },
            report.iters,
            report.err,
            report.delta,
            report.converged,
            report.seconds * 1e3,
            report.seconds * 1e3 / report.iters.max(1) as f64,
        );
        let roof = Roofline::regenerated(m as u64, n as u64, report.iters as u64);
        report_trace(&session, &trace, roof, report.seconds);
        return 0;
    }

    // Sparse path: --sparse <threshold> converts the plan to CSR (dropping
    // entries <= threshold) and solves on the fused CSR backend. Same
    // loud-failure contract as --par/--kernel: a typo or an unsupported
    // solver must not silently fall back to the dense path.
    if let Some(raw) = a.flags.get("sparse") {
        let threshold = match raw.parse::<f32>() {
            Ok(t) => t,
            Err(_) => {
                eprintln!("error: --sparse expects a numeric threshold, got {raw:?}");
                return 1;
            }
        };
        if solver != SolverKind::MapUot {
            eprintln!("error: --sparse runs the fused MAP-UOT CSR kernel (use --solver mapuot)");
            return 1;
        }
        // The CSR sweep runs its own unrolled primitives — the dense
        // kernel/tile knobs do not apply, so accepting them here would
        // silently measure nothing (the exact failure mode the loud
        // contract above exists to prevent).
        if a.flags.contains_key("kernel") || a.flags.contains_key("tile") {
            eprintln!(
                "error: --kernel/--tile select the dense SIMD backend and do not apply to \
                 --sparse (the CSR sweep runs the unrolled CSR primitives)"
            );
            return 1;
        }
        let problem = Problem::random(m, n, fi, seed);
        let sp = match SparseProblem::from_problem(&problem, threshold) {
            Ok(sp) => sp,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        let mut session = builder.build_sparse(&sp);
        let report = match session.solve_sparse(&sp) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("error: {e}");
                return 1;
            }
        };
        println!(
            "MAP-UOT sparse solve {m}x{n} fi={fi} [threshold={threshold} nnz={} density={:.4}]: \
             iters={} err={:.3e} delta={:.3e} converged={} time={:.1}ms ({:.2} ms/iter)",
            sp.nnz(),
            sp.plan.density(),
            report.iters,
            report.err,
            report.delta,
            report.converged,
            report.seconds * 1e3,
            report.seconds * 1e3 / report.iters.max(1) as f64,
        );
        let roof = Roofline::materialized(
            sp.nnz() as u64,
            solver.passes_per_iter() as u64,
            solver.accesses_per_element() as u64,
            4,
            report.iters as u64,
        );
        report_trace(&session, &trace, roof, report.seconds);
        return 0;
    }

    let problem = Problem::random(m, n, fi, seed);
    let mut session = builder.kernel(kernel).tile(tile).build(&problem);
    let policy = session.policy();
    let report = match session.solve(&problem) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("error: {e}");
            return 1;
        }
    };
    println!(
        "{} solve {m}x{n} fi={fi} [kernel={} tile={}]: iters={} err={:.3e} delta={:.3e} converged={} time={:.1}ms ({:.2} ms/iter)",
        solver.name(),
        policy.kind().name(),
        if policy.tile_cols() == 0 { "off".to_string() } else { policy.tile_cols().to_string() },
        report.iters,
        report.err,
        report.delta,
        report.converged,
        report.seconds * 1e3,
        report.seconds * 1e3 / report.iters.max(1) as f64,
    );
    let roof = Roofline::materialized(
        (m * n) as u64,
        solver.passes_per_iter() as u64,
        solver.accesses_per_element() as u64,
        4,
        report.iters as u64,
    );
    report_trace(&session, &trace, roof, report.seconds);
    let plan = session.into_plan();
    let _ = plan;
    0
}

/// Shared tail of every traced `solve` path: the analytic roofline line
/// plus the span-trace export (no-op without `--trace`).
fn report_trace(session: &SolverSession, trace: &Option<String>, roof: Roofline, seconds: f64) {
    if let Some(path) = trace {
        println!("{}", roof.cli_line(seconds));
        match session.export_trace() {
            Ok(events) => println!("trace: {events} spans -> {path}"),
            Err(e) => eprintln!("trace export failed ({path}): {e}"),
        }
    }
}

fn cmd_serve(a: &Args) -> i32 {
    run_or_die(|| {
        let backend = if a.str("backend", "native") == "pjrt" {
            Backend::Pjrt
        } else {
            Backend::Native
        };
        let cfg = ServiceConfig {
            workers: a.get("workers", 4usize),
            backend,
            artifacts_dir: a.str("artifacts", "artifacts"),
            stop: StopRule { max_iter: a.get("max-iter", 400usize), ..Default::default() },
            trace: a.flags.get("trace").cloned(),
            ..ServiceConfig::default()
        };
        let requests = a.get("requests", 64usize);
        let size = a.get("size", 256usize);
        let svc = Service::start(cfg)?;

        let timer = Timer::start();
        let rxs: Vec<_> = (0..requests)
            .filter_map(|i| svc.submit(Problem::random(size, size, 0.8, i as u64)).ok())
            .collect();
        let accepted = rxs.len();
        let mut ok = 0;
        for rx in rxs {
            if rx.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
                ok += 1;
            }
        }
        let wall = timer.elapsed().as_secs_f64();
        let m = svc.metrics();
        // Latency decomposes into queue wait + solve (PR 10): the p99s are
        // reported separately so a deep queue is not mistaken for a slow
        // solver.
        println!(
            "serve: {ok}/{accepted} ok of {requests} submitted in {wall:.2}s \
             ({:.1} req/s) | mean batch {:.2} | mean solve {:.1}ms + wait {:.1}ms | \
             p99<= {:.0}ms solve / {:.0}ms wait | rejected {}",
            ok as f64 / wall,
            m.mean_batch_size,
            m.mean_latency_ms,
            m.mean_wait_ms,
            m.latency_percentile_ms(99.0),
            m.wait_percentile_ms(99.0),
            m.rejected,
        );
        svc.shutdown();
        Ok(())
    })
}

/// `stats` — run an in-process demo load and print the versioned metrics
/// JSON ([`coordinator::stats_json`]); with `--check-trace <path>`,
/// validate a previously exported trace file instead (the CI gate for the
/// traced-solve leg).
fn cmd_stats(a: &Args) -> i32 {
    if let Some(path) = a.flags.get("check-trace") {
        return match check_trace_file(path) {
            Ok(events) => {
                println!("trace ok: {events} events in {path}");
                0
            }
            Err(e) => {
                eprintln!("error: invalid trace {path}: {e}");
                1
            }
        };
    }
    run_or_die(|| {
        let cfg = ServiceConfig {
            workers: a.get("workers", 2usize),
            stop: StopRule { max_iter: a.get("max-iter", 200usize), ..Default::default() },
            trace: a.flags.get("trace").cloned(),
            ..ServiceConfig::default()
        };
        let requests = a.get("requests", 16usize);
        let size = a.get("size", 128usize);
        let svc = Service::start(cfg)?;
        let rxs: Vec<_> = (0..requests)
            .filter_map(|i| svc.submit(Problem::random(size, size, 0.8, i as u64)).ok())
            .collect();
        for rx in rxs {
            let _ = rx.recv();
        }
        println!("{}", svc.stats_json());
        svc.shutdown();
        Ok(())
    })
}

/// Validate an exported trace file: chrome://tracing JSON goes through
/// the structural validator; `.jsonl` exports are checked line-by-line
/// (every non-empty line one brace-delimited event object).
fn check_trace_file(path: &str) -> std::result::Result<usize, String> {
    let raw = std::fs::read_to_string(path).map_err(|e| e.to_string())?;
    if path.ends_with(".jsonl") {
        let mut events = 0usize;
        for (i, line) in raw.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if !line.starts_with('{') || !line.ends_with('}') {
                return Err(format!("line {} is not an event object", i + 1));
            }
            events += 1;
        }
        if events == 0 {
            return Err("no events".to_string());
        }
        Ok(events)
    } else {
        let events = telemetry::validate_perfetto(&raw)?;
        if events == 0 {
            return Err("no events".to_string());
        }
        Ok(events)
    }
}

fn cmd_app(which: &str, a: &Args) -> i32 {
    let solver = SolverKind::parse(&a.str("solver", "mapuot")).unwrap_or(SolverKind::MapUot);
    match which {
        "color" => {
            let out = apps::color_transfer::run(apps::color_transfer::Config {
                solver,
                ..Default::default()
            });
            print_app("color-transfer", &out.report);
        }
        "domain" => {
            let out =
                apps::domain_adapt::run(apps::domain_adapt::Config { solver, ..Default::default() });
            print_app("domain-adaptation", &out.report);
            println!("  accuracy: {:.1}%", out.accuracy * 100.0);
        }
        "bayes" => {
            let out = apps::bayesian::run(apps::bayesian::Config { solver, ..Default::default() });
            print_app("cooperative-bayesian", &out.report);
            println!("  marginal err: {:.2e}", out.marginal_err);
        }
        "filter" => {
            let out = apps::sinkhorn_filter::run(apps::sinkhorn_filter::Config {
                solver,
                ..Default::default()
            });
            print_app("sinkhorn-filter", &out.report);
            println!("  correspondence accuracy: {:.1}%", out.accuracy * 100.0);
        }
        "entropic2d" => {
            let out = apps::entropic2d::run(apps::entropic2d::Config { solver, ..Default::default() });
            print_app("2d-entropic-uot", &out.report);
            println!("  plan mass {:.3}, mean transport distance {:.2} cells", out.plan_mass, out.mean_distance);
        }
        "wmd" => {
            let out = apps::wmd::run(apps::wmd::Config::default());
            print_app("sinkhorn-wmd", &out.report);
            println!("  1-NN topic accuracy: {:.1}%", out.knn_accuracy * 100.0);
        }
        other => {
            eprintln!("unknown app {other:?} (color|domain|bayes|filter|entropic2d|wmd)");
            return 2;
        }
    }
    0
}

fn print_app(name: &str, r: &apps::AppReport) {
    println!(
        "{name} [{}]: total {:.1}ms, uot {:.1}ms ({:.1}%), {} iters",
        r.solver.name(),
        r.total_s * 1e3,
        r.uot_s * 1e3,
        r.uot_share() * 100.0,
        r.iters
    );
}

fn cmd_fig(which: &str) -> i32 {
    match which {
        "2" => figures::fig02().print(),
        "3" => figures::fig03().print(),
        "4" => figures::fig04().print(),
        "5" => figures::fig05().print(),
        "8" => {
            let (a, b) = figures::fig08();
            a.print();
            b.print();
            figures::fig08_cpu().print();
        }
        "9" => {
            let (t, s) = figures::fig09();
            t.print();
            println!("summary: {s}");
        }
        "10" => figures::fig10().print(),
        "11" => figures::fig11().print(),
        "12" => {
            figures::fig12().print();
            figures::fig12_pool().print();
        }
        "13" => {
            let (t, s) = figures::fig13();
            t.print();
            println!("summary: {s}");
        }
        "14" => figures::fig14().print(),
        "15" => figures::fig15().print(),
        "16" => figures::fig16().print(),
        "17" => {
            let (t, s) = figures::fig17();
            t.print();
            println!("summary: {s}");
        }
        "all" => figures::all(),
        other => {
            eprintln!("unknown figure {other:?} (2-5, 8-17, all)");
            return 2;
        }
    }
    0
}

fn cmd_info(a: &Args) -> i32 {
    run_or_die(|| {
        println!("map-uot {} — three-layer rust+jax+pallas stack", env!("CARGO_PKG_VERSION"));
        let dir = a.str("artifacts", "artifacts");
        match Runtime::open(&dir) {
            Ok(rt) => {
                println!("pjrt platform: {}", rt.platform());
                println!("artifacts in {dir:?}:");
                for m in rt.manifest().iter() {
                    println!(
                        "  {} ({:?} {}x{} steps={} block_m={})",
                        m.name, m.kind, m.m, m.n, m.steps, m.block_m
                    );
                }
            }
            Err(e) => println!("no artifacts: {e}"),
        }
        Ok(())
    })
}

fn run_or_die(f: impl FnOnce() -> Result<()>) -> i32 {
    match f() {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}
