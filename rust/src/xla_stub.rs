//! Offline stub of the `xla` PJRT binding (xla-rs API surface).
//!
//! The build environment is fully offline and ships no XLA extension, so
//! the crate cannot link the real `xla` crate. This module mirrors the
//! exact subset of its API that [`crate::runtime`] uses; every entry
//! point fails fast with [`Error::unavailable`], which `Runtime::open`
//! surfaces as a typed `Error::Runtime` — the PJRT backend degrades into
//! a clean "unavailable" error while the native solvers (the tier-1
//! surface) stay fully functional.
//!
//! Swapping in the real binding is a two-line change per importer:
//! replace `use crate::xla_stub as xla;` with the real crate once it is
//! available in the build environment (see `coordinator::pjrt_exec` for
//! the threading constraints the real client imposes: `PjRtClient` is
//! `Rc`-based and must stay on one thread).

/// Error from the (stubbed) XLA runtime.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Self {
        Error("PJRT backend unavailable: built against the in-repo xla stub (offline build)".into())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stubbed PJRT client (`xla::PjRtClient`).
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding opens the CPU plugin; the stub fails fast.
    pub fn cpu() -> Result<Self, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

/// Stubbed compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Mirrors xla-rs: returns per-device, per-output buffers.
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Stubbed device buffer.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Stubbed HLO module proto (text-parsed artifacts).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self, Error> {
        Err(Error::unavailable())
    }
}

/// Stubbed XLA computation.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation
    }
}

/// Stubbed host literal.
pub struct Literal;

impl Literal {
    /// 1-D literal from a host slice (real binding copies; stub is inert —
    /// it can never reach an executable, which fails at compile()).
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal), Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_and_typed() {
        assert!(PjRtClient::cpu().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("unavailable"), "{msg}");
        assert!(HloModuleProto::from_text_file("x").is_err());
        assert!(Literal::vec1(&[1f32]).reshape(&[1]).is_err());
    }
}
