//! Plain-text table formatting for the figure harnesses.

/// A simple column-aligned table printer.
#[derive(Debug, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: format mixed display values.
    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .zip(&widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["size", "speedup"]);
        t.row(&["1024".into(), "2.9".into()]);
        t.row(&["10240x4".into(), "1.6".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("size"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_checked() {
        Table::new("t", &["a", "b"]).row(&["only-one".into()]);
    }
}
