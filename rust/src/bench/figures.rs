//! Figure/table generators: one function per paper figure.
//!
//! Each returns (and can print) the same rows/series the paper reports.
//! The `cargo bench` harnesses (`rust/benches/fig*.rs`) and the CLI
//! (`map-uot fig N`) are thin wrappers over these. Native-solver figures
//! measure real wall time on this machine; hardware-gated figures run the
//! simulators (DESIGN.md §Substitutions).

use crate::algo::pool::{AccArena, ThreadPool};
use crate::algo::{self, parallel, SolverKind};
use crate::apps;
use crate::bench::{fast_mode, measure, speedup_summary, Policy, Table};
use crate::config::presets;
use crate::sim::gpu::model::Part;
use crate::sim::gpu::{self, TileConfig};
use crate::sim::{cluster, memtrace, roofline};

/// Square sizes used by the single-node figures (paper: 1024..10240).
pub fn square_sizes() -> Vec<usize> {
    if fast_mode() {
        vec![256, 512]
    } else {
        // 8192 (268 MB) exceeds even this host's 260 MB LLC, where the
        // paper's DRAM-traffic argument fully applies; smaller sizes show
        // the LLC-resident regime (EXPERIMENTS.md discusses both).
        vec![1024, 2048, 4096, 8192]
    }
}

/// Rectangular (M, N) pairs (paper Fig. 9/13 right panels).
pub fn rect_sizes() -> Vec<(usize, usize)> {
    if fast_mode() {
        vec![(256, 1024), (1024, 256)]
    } else {
        vec![(1024, 4096), (4096, 1024), (512, 8192)]
    }
}

/// Sizes for the trace-driven cache figures (miss rates are pattern-driven
/// and size-invariant once the matrix exceeds L2, so the sim stops at 4096).
pub fn cache_sizes() -> Vec<usize> {
    if fast_mode() { vec![256, 512] } else { vec![1024, 2048, 4096] }
}

/// Median seconds per iteration of `kind` on an `m × n` problem.
pub fn iter_seconds(kind: SolverKind, m: usize, n: usize, threads: usize) -> f64 {
    let p = algo::Problem::random(m, n, 0.7, 42);
    let solver = algo::solver_for(kind);
    let mut ws = algo::Workspace::new(m, n, threads);
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    // Measure a small batch of iterations to amortize timer noise; the
    // reused workspace keeps allocation out of the measured loop.
    let iters_per_rep = if m * n >= 4096 * 4096 { 2 } else { 4 };
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let sec = measure(policy, || {
        for _ in 0..iters_per_rep {
            solver.iterate(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
    });
    sec / iters_per_rep as f64
}

/// Fig. 2: proportion of application time spent in UOT + growth with size.
pub fn fig02() -> Table {
    let mut t = Table::new(
        "Fig 2: share of end-to-end time spent in UOT (MAP-UOT solver)",
        &["application", "size", "uot_ms", "total_ms", "uot_share"],
    );
    let scale = if fast_mode() { 1 } else { 4 };

    let bay = apps::bayesian::run(apps::bayesian::Config {
        hypotheses: 256 * scale,
        data: 256 * scale,
        max_iter: 2000,
        ..Default::default()
    });
    push_app(&mut t, "cooperative-bayesian", 256 * scale, &bay.report);

    let e2d = apps::entropic2d::run(apps::entropic2d::Config {
        grid: 8 * scale.min(4),
        max_iter: 500,
        ..Default::default()
    });
    push_app(&mut t, "2d-entropic-uot", (8usize * scale.min(4)).pow(2), &e2d.report);

    let ct = apps::color_transfer::run(apps::color_transfer::Config {
        palette: 256 * scale.min(2),
        max_iter: 500,
        ..Default::default()
    });
    push_app(&mut t, "color-transfer", 256 * scale.min(2), &ct.report);

    let sf = apps::sinkhorn_filter::run(apps::sinkhorn_filter::Config {
        points: 128 * scale,
        max_iter: 1000,
        ..Default::default()
    });
    push_app(&mut t, "sinkhorn-filter", 128 * scale, &sf.report);

    // Domain adaptation share vs matrix size (bottom panel of Fig. 2).
    for npc in if fast_mode() { vec![16, 32] } else { vec![32, 64, 128, 256] } {
        let da = apps::domain_adapt::run(apps::domain_adapt::Config {
            n_per_class: npc,
            classes: 4,
            max_iter: 1000,
            ..Default::default()
        });
        push_app(&mut t, "domain-adaptation", npc * 4, &da.report);
    }
    t
}

fn push_app(t: &mut Table, name: &str, size: usize, r: &apps::AppReport) {
    t.row(&[
        name.into(),
        format!("{size}"),
        format!("{:.2}", r.uot_s * 1e3),
        format!("{:.2}", r.total_s * 1e3),
        format!("{:.1}%", r.uot_share() * 100.0),
    ]);
}

/// Fig. 3: Roofline model — Eq. 1 intensities vs ridge points.
pub fn fig03() -> Table {
    let mut t = Table::new(
        "Fig 3: global-memory Roofline (Eq. 1)",
        &["machine", "solver", "I (flop/byte)", "attainable GF/s", "ridge point"],
    );
    let machines = [presets::i9_12900k_roofline(), presets::rtx_3090ti_roofline()];
    for row in roofline::figure3(&machines, 4096, 4096) {
        t.row(&[
            row.machine.into(),
            row.kind.name().into(),
            format!("{:.3}", row.intensity),
            format!("{:.1}", row.attainable_gflops),
            format!("{:.1}", row.ridge_point),
        ]);
    }
    t
}

/// Fig. 4: baseline (POT) L1/L2 miss rates on the 12900K cache model.
pub fn fig04() -> Table {
    let mut t = Table::new(
        "Fig 4: baseline (POT) cache miss rates (12900K model)",
        &["size", "L1 miss", "L2 miss"],
    );
    let cfg = presets::i9_12900k_caches();
    for &s in &cache_sizes() {
        let st = memtrace::simulate(cfg, SolverKind::Pot, s, s, 1);
        t.row(&[
            format!("{s}x{s}"),
            format!("{:.2}%", st.l1_miss_rate() * 100.0),
            format!("{:.2}%", st.l2_miss_rate() * 100.0),
        ]);
    }
    t
}

/// Fig. 5: baseline GPU global load/store throughput (3090 Ti model).
pub fn fig05() -> Table {
    let mut t = Table::new(
        "Fig 5: baseline (CuPy) global throughput (3090 Ti model)",
        &["size", "load GB/s", "store GB/s", "load %peak", "store %peak"],
    );
    let g = presets::rtx_3090ti_gpu();
    for &s in &[1024usize, 2048, 4096, 8192, 10240] {
        let th = gpu::throughput_gbs(&g, s, s, false);
        t.row(&[
            format!("{s}x{s}"),
            format!("{:.0}", th.load_gbs),
            format!("{:.0}", th.store_gbs),
            format!("{:.1}%", th.load_gbs / g.peak_bw_gbs * 100.0),
            format!("{:.1}%", th.store_gbs / g.peak_bw_gbs * 100.0),
        ]);
    }
    t
}

/// Fig. 8: GPU tiling-parameter sweep at 10240² (Ty = 2 for part ②).
pub fn fig08() -> (Table, Table) {
    let g = presets::rtx_3090ti_gpu();
    let nys = [1usize, 2, 4, 8, 16];
    let txs = [32usize, 64, 128, 256, 512];
    let mk = |part: Part, ty: usize| {
        let title = match part {
            Part::Part2 => "Fig 8 (part 2): kernel ms over Tx x Ny, 10240^2",
            Part::Part4 => "Fig 8 (part 4): kernel ms over Tx x Ny, 10240^2",
        };
        let mut headers = vec!["Tx\\Ny".to_string()];
        headers.extend(nys.iter().map(|n| n.to_string()));
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(title, &hdr_refs);
        for &tx in &txs {
            let mut row = vec![tx.to_string()];
            for &ny in &nys {
                let ms = gpu::model::kernel_time_ms(&g, part, TileConfig { tx, ty, ny }, 10240, 10240);
                row.push(format!("{ms:.3}"));
            }
            t.row(&row);
        }
        t
    };
    (mk(Part::Part2, 2), mk(Part::Part4, 1))
}

/// Fig. 8 companion (measured): the **real CPU tiled kernel** — MAP-UOT
/// ms/iteration across shapes × tile widths × kernel backends, on this
/// host. This is the CPU analogue of the paper's GPU tiling sweep: the
/// `fig08_tiling_sweep` bench harness runs it (the GPU tables above model
/// the paper's 3090 Ti) and emits `BENCH_tiling.json` when
/// `MAP_UOT_TILING_JSON` is set (the harness defaults it to the committed
/// repo-root snapshot; the CLI `fig 8` stays side-effect-free). The env
/// var is distinct from fig12's `MAP_UOT_BENCH_JSON` so one process can
/// emit both series without clobbering either.
///
/// Read it as: tiling must be free at LLC-resident sizes (single panel or
/// cheap panel loop) and win once the reused per-row vectors
/// (`Factor_col`/`inv_fcol`/`NextSum_col`) outgrow L1/L2 — i.e. at large
/// `n`. Kernel rows compare `unrolled` (auto-vectorized) against the
/// runtime-detected best (AVX2+FMA + NT stores where available).
pub fn fig08_cpu() -> Table {
    let shapes: &[(usize, usize)] = if fast_mode() {
        &[(64, 256), (48, 2048)]
    } else {
        // n spans LLC-resident to DRAM-bound; 1024×16384 (64 MB) and
        // 512×32768 are where the acceptance criterion ("faster at
        // n >= 16k") is read off.
        &[(4096, 1024), (2048, 4096), (1024, 16384), (512, 32768)]
    };
    let tiles: &[(&str, crate::algo::TileSpec)] = &[
        ("off", crate::algo::TileSpec::Off),
        ("auto", crate::algo::TileSpec::Auto),
        ("256", crate::algo::TileSpec::Cols(256)),
        ("1024", crate::algo::TileSpec::Cols(1024)),
        ("4096", crate::algo::TileSpec::Cols(4096)),
    ];
    let detected = crate::algo::KernelKind::detect();
    let mut kernels = vec![crate::algo::KernelKind::Unrolled];
    if detected != crate::algo::KernelKind::Unrolled {
        kernels.push(detected);
    }
    let mut headers = vec!["matrix".to_string(), "kernel".to_string()];
    headers.extend(tiles.iter().map(|(name, _)| format!("tile={name}")));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 8 (measured): MAP-UOT ms/iter, CPU tiled kernel x tile width",
        &hdr,
    );
    let mut json_rows = String::new();
    for &(m, n) in shapes {
        for &kernel in &kernels {
            let mut cells = vec![format!("{m}x{n}"), kernel.name().to_string()];
            for (tile_name, tile) in tiles {
                let sec = mapuot_iter_seconds_policy(m, n, kernel, *tile);
                if !json_rows.is_empty() {
                    json_rows.push(',');
                }
                json_rows.push_str(&format!(
                    "\n    {{\"m\": {m}, \"n\": {n}, \"kernel\": \"{}\", \
                     \"tile\": \"{tile_name}\", \"ms_per_iter\": {:.4}}}",
                    kernel.name(),
                    sec * 1e3
                ));
                cells.push(format!("{:.3}", sec * 1e3));
            }
            t.row(&cells);
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"fig08_tiling_sweep\",\n  \"unit\": \"ms_per_iter\",\n  \
         \"kernel_detected\": \"{}\",\n  \"rows\": [{json_rows}\n  ]\n}}\n",
        detected.name()
    );
    if let Ok(path) = std::env::var("MAP_UOT_TILING_JSON") {
        match std::fs::write(&path, &json) {
            Ok(()) => println!("[fig08_cpu] wrote {path}"),
            Err(e) => eprintln!("[fig08_cpu] could not write {path}: {e}"),
        }
    }
    t
}

/// Median seconds per MAP-UOT iteration under an explicit kernel/tile
/// policy (serial; the tiling story is per-core cache residency).
fn mapuot_iter_seconds_policy(
    m: usize,
    n: usize,
    kernel: crate::algo::KernelKind,
    tile: crate::algo::TileSpec,
) -> f64 {
    let p = algo::Problem::random(m, n, 0.7, 42);
    let solver = algo::solver_for(SolverKind::MapUot);
    let mut ws = algo::Workspace::new(m, n, 1);
    ws.set_policy(crate::algo::KernelPolicy::for_shape(kernel, tile, m, n));
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    let iters_per_rep = if m * n >= 4096 * 4096 { 2 } else { 4 };
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let sec = measure(policy, || {
        for _ in 0..iters_per_rep {
            solver.iterate(&mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, &mut ws);
        }
    });
    sec / iters_per_rep as f64
}

/// Fig. 9: single-threaded native performance, square + rectangular.
pub fn fig09() -> (Table, String) {
    let mut t = Table::new(
        "Fig 9: single-threaded time per iteration (ms) + speedups",
        &["size", "POT", "COFFEE", "MAP-UOT", "vs POT", "vs COFFEE"],
    );
    let mut sp_pot = Vec::new();
    let mut sp_cof = Vec::new();
    let mut shapes: Vec<(usize, usize)> = square_sizes().iter().map(|&s| (s, s)).collect();
    shapes.extend(rect_sizes());
    for (m, n) in shapes {
        let pot = iter_seconds(SolverKind::Pot, m, n, 1);
        let cof = iter_seconds(SolverKind::Coffee, m, n, 1);
        let map = iter_seconds(SolverKind::MapUot, m, n, 1);
        sp_pot.push(pot / map);
        sp_cof.push(cof / map);
        t.row(&[
            format!("{m}x{n}"),
            format!("{:.2}", pot * 1e3),
            format!("{:.2}", cof * 1e3),
            format!("{:.2}", map * 1e3),
            format!("{:.2}x", pot / map),
            format!("{:.2}x", cof / map),
        ]);
    }
    let summary = format!(
        "vs POT: {} | vs COFFEE: {}",
        speedup_summary(&sp_pot),
        speedup_summary(&sp_cof)
    );
    (t, summary)
}

/// Fig. 10: thread scaling, normalized to single-threaded POT.
///
/// Two panels: *measured* on this machine (meaningful only when it has
/// multiple cores — the CI testbed has one, where this degenerates into a
/// threading-overhead check) and *projected* on the paper's 12900K via the
/// bandwidth-saturation model (`sim::multicore`), which reproduces the
/// paper's 3.3x / 4.0x / 7.2x plateaus.
pub fn fig10() -> Table {
    let size = if fast_mode() { 512 } else { 4096 };
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let mut t = Table::new(
        format!("Fig 10: scaling at {size}^2, speedup vs POT 1T (measured on {cores}-core host | projected 12900K)"),
        &["threads", "POT", "COFFEE", "MAP-UOT"],
    );
    let machine = presets::i9_12900k_roofline();
    let base = iter_seconds(SolverKind::Pot, size, size, 1);
    for &threads in &[1usize, 2, 4, 8, 16] {
        let cells: Vec<String> = SolverKind::ALL
            .iter()
            .map(|&k| {
                let measured = base / iter_seconds(k, size, size, threads);
                let projected =
                    crate::sim::multicore::speedup_vs_pot1(&machine, k, size, size, threads);
                format!("{measured:.2}x|{projected:.2}x")
            })
            .collect();
        t.row(&[format!("{threads}"), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
    }
    t
}

/// Fig. 11: cache-miss reduction vs POT and COFFEE.
pub fn fig11() -> Table {
    let mut t = Table::new(
        "Fig 11: MAP-UOT cache-miss-rate reduction (12900K model)",
        &["size", "L1 vs POT", "L1 vs COFFEE", "L2 vs POT", "L2 vs COFFEE"],
    );
    let cfg = presets::i9_12900k_caches();
    for &s in &cache_sizes() {
        let pot = memtrace::simulate(cfg, SolverKind::Pot, s, s, 1);
        let cof = memtrace::simulate(cfg, SolverKind::Coffee, s, s, 1);
        let map = memtrace::simulate(cfg, SolverKind::MapUot, s, s, 1);
        let red = |a: f64, b: f64| format!("{:.1}%", (1.0 - b / a) * 100.0);
        t.row(&[
            format!("{s}x{s}"),
            red(pot.l1_miss_rate(), map.l1_miss_rate()),
            red(cof.l1_miss_rate(), map.l1_miss_rate()),
            red(pot.l2_miss_rate(), map.l2_miss_rate()),
            red(cof.l2_miss_rate(), map.l2_miss_rate()),
        ]);
    }
    t
}

/// Fig. 12: L1 miss rate vs thread count (false-sharing check) — padded
/// design vs naive shared accumulators ablation.
pub fn fig12() -> Table {
    let mut t = Table::new(
        "Fig 12: MAP-UOT L1 miss rate vs threads (padded | naive accumulators)",
        &["matrix", "T=1", "T=2", "T=4", "T=8", "T=16"],
    );
    let l1 = presets::i9_12900k_caches().l1;
    // n = 12 (48 B accumulator rows, unaligned thread boundaries) is the
    // shape where naive shared accumulators false-share; n >= 16 with
    // aligned rows is the paper's "eliminated" regime (§5.2.4).
    let shapes: &[(usize, usize)] = if fast_mode() {
        &[(128, 12), (256, 128)]
    } else {
        &[(1024, 12), (1024, 16), (512, 2048), (2048, 2048)]
    };
    for &(m, n) in shapes {
        let mut cells = vec![format!("{m}x{n}")];
        for &threads in &[1usize, 2, 4, 8, 16] {
            let padded = memtrace::simulate_mapuot_threads(l1, m, n, threads, true);
            let naive = memtrace::simulate_mapuot_threads(l1, m, n, threads, false);
            cells.push(format!(
                "{:.2}%|{:.2}%",
                padded.l1_miss_rate() * 100.0,
                naive.l1_miss_rate() * 100.0
            ));
        }
        t.row(&cells);
    }
    t
}

/// Fig. 12 companion (measured): MAP-UOT iterations/second under the
/// legacy spawn-per-iteration scope backend vs the persistent worker pool,
/// plus the accumulator ablation (cache-line-padded arena vs packed
/// unpadded arena vs the pre-arena `Vec<Vec<f32>>` rows).
///
/// The small-N shapes are where per-iteration dispatch overhead dominates
/// — the pool's biggest win; the square shape shows the memory-bound
/// regime where the backends converge. When `MAP_UOT_BENCH_JSON` is set
/// (the `fig12_false_sharing` bench harness defaults it to
/// `BENCH_pool.json`), also emits the machine-readable series so the perf
/// trajectory can be tracked run-over-run; the plain CLI `fig 12` stays
/// side-effect-free.
pub fn fig12_pool() -> Table {
    let threads: &[usize] = if fast_mode() { &[1, 2, 4] } else { &[1, 2, 4, 8, 16] };
    let shapes: &[(usize, usize)] = if fast_mode() {
        &[(256, 64), (256, 256)]
    } else {
        &[(1024, 64), (1024, 1024), (4096, 256)]
    };
    let mut headers = vec!["matrix".to_string(), "backend".to_string()];
    headers.extend(threads.iter().map(|t| format!("T={t}")));
    let hdr: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut t = Table::new(
        "Fig 12b (measured): MAP-UOT iterations/sec by backend x threads",
        &hdr,
    );
    let mut json_rows = String::new();
    for &(m, n) in shapes {
        for backend in ["spawn", "pool", "pool-unpadded", "vecvec"] {
            let mut cells = vec![format!("{m}x{n}"), backend.to_string()];
            for &tc in threads {
                let ips = mapuot_iters_per_sec(backend, m, n, tc);
                if !json_rows.is_empty() {
                    json_rows.push(',');
                }
                json_rows.push_str(&format!(
                    "\n    {{\"m\": {m}, \"n\": {n}, \"backend\": \"{backend}\", \
                     \"threads\": {tc}, \"iters_per_sec\": {ips:.2}}}"
                ));
                cells.push(format!("{ips:.0}"));
            }
            t.row(&cells);
        }
    }
    let json = format!(
        "{{\n  \"bench\": \"fig12_pool\",\n  \"unit\": \"iters_per_sec\",\n  \
         \"rows\": [{json_rows}\n  ]\n}}\n"
    );
    // The CLI path stays side-effect-free: only an explicit opt-in (set by
    // the bench harness, or the user) writes the JSON file.
    if let Ok(path) = std::env::var("MAP_UOT_BENCH_JSON") {
        match std::fs::write(&path, &json) {
            Ok(()) => println!("[fig12_pool] wrote {path}"),
            Err(e) => eprintln!("[fig12_pool] could not write {path}: {e}"),
        }
    }
    t
}

/// Median MAP-UOT iterations/second for one Fig. 12b configuration.
fn mapuot_iters_per_sec(backend: &str, m: usize, n: usize, threads: usize) -> f64 {
    let p = algo::Problem::random(m, n, 0.7, 42);
    let mut plan = p.plan.clone();
    let mut colsum = plan.col_sums();
    let mut fcol = vec![0f32; n];
    let iters_per_rep = if m * n >= 1024 * 1024 { 4 } else { 16 };
    let policy = Policy { warmup: 1, reps: if fast_mode() { 3 } else { 5 } };
    let sec = match backend {
        "pool" | "pool-unpadded" => {
            // The pool is built once, outside the measured loop — that is
            // the whole point of the persistent engine.
            let pool = ThreadPool::new(threads);
            let mut acc = if backend == "pool" {
                AccArena::padded(threads, n)
            } else {
                AccArena::unpadded(threads, n)
            };
            measure(policy, || {
                for _ in 0..iters_per_rep {
                    parallel::mapuot_iterate_pool(
                        &mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, &pool, &mut fcol, &mut acc,
                    );
                }
            })
        }
        "spawn" => {
            let mut acc = AccArena::padded(threads, n);
            measure(policy, || {
                for _ in 0..iters_per_rep {
                    parallel::mapuot_iterate_into(
                        &mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, threads, &mut fcol, &mut acc,
                    );
                }
            })
        }
        _ => {
            let mut acc: Vec<Vec<f32>> = (0..threads.max(1)).map(|_| vec![0f32; n]).collect();
            measure(policy, || {
                for _ in 0..iters_per_rep {
                    mapuot_iterate_vecvec(
                        &mut plan, &mut colsum, &p.rpd, &p.cpd, p.fi, threads, &mut fcol, &mut acc,
                    );
                }
            })
        }
    };
    iters_per_rep as f64 / sec
}

/// The pre-arena accumulator layout — separately allocated `Vec<Vec<f32>>`
/// rows, uniform `ceil(m/t)` blocks, scope dispatch — kept **only** as the
/// Fig. 12b ablation baseline; every production path uses the padded
/// [`AccArena`].
#[allow(clippy::too_many_arguments)]
fn mapuot_iterate_vecvec(
    plan: &mut crate::util::Matrix,
    colsum: &mut [f32],
    rpd: &[f32],
    cpd: &[f32],
    fi: f32,
    threads: usize,
    fcol: &mut [f32],
    acc: &mut [Vec<f32>],
) {
    let (m, n) = (plan.rows(), plan.cols());
    let t = threads.max(1).min(m.max(1)).min(acc.len().max(1));
    let rows_per = m.div_ceil(t);
    crate::algo::scaling::factors_into(fcol, cpd, colsum, fi);
    let fcol_ref: &[f32] = fcol;
    std::thread::scope(|s| {
        let handles: Vec<_> = plan
            .as_mut_slice()
            .chunks_mut(rows_per * n)
            .zip(rpd.chunks(rows_per))
            .zip(acc.iter_mut())
            .map(|((block, rpd_block), local)| {
                s.spawn(move || {
                    local.fill(0.0);
                    crate::algo::mapuot::fused_rows(block, n, rpd_block, fcol_ref, fi, local);
                })
            })
            .collect();
        for h in handles {
            h.join().expect("worker panicked");
        }
    });
    let used = m.div_ceil(rows_per);
    colsum.fill(0.0);
    for local in &acc[..used] {
        for (sum, &v) in colsum.iter_mut().zip(local.iter()) {
            *sum += v;
        }
    }
}

/// Fig. 13: GPU performance vs POT (3090 Ti model).
pub fn fig13() -> (Table, String) {
    let g = presets::rtx_3090ti_gpu();
    let (t2, t4) = (TileConfig::part2_default(), TileConfig::part4_default());
    let mut t = Table::new(
        "Fig 13: GPU iteration time (ms) and speedup (3090 Ti model)",
        &["size", "POT/CuPy", "MAP-UOT", "speedup"],
    );
    let mut sps = Vec::new();
    let mut shapes: Vec<(usize, usize)> =
        [512usize, 1024, 2048, 4096, 8192, 10240].iter().map(|&s| (s, s)).collect();
    shapes.extend([(1024, 4096), (4096, 1024), (2048, 10240)]);
    for (m, n) in shapes {
        let pot = gpu::pot_iter_ms(&g, m, n);
        let map = gpu::mapuot_iter_ms(&g, m, n, t2, t4);
        sps.push(pot / map);
        t.row(&[
            format!("{m}x{n}"),
            format!("{pot:.3}"),
            format!("{map:.3}"),
            format!("{:.2}x", pot / map),
        ]);
    }
    let s = speedup_summary(&sps);
    (t, s)
}

/// Fig. 14: global-throughput increment over POT (3090 Ti model).
pub fn fig14() -> Table {
    let g = presets::rtx_3090ti_gpu();
    let mut t = Table::new(
        "Fig 14: achieved bandwidth, MAP-UOT vs CuPy baseline (3090 Ti model)",
        &["size", "base ld/st GB/s", "fused ld/st GB/s", "store +%", "total util +%"],
    );
    for &s in &[1024usize, 2048, 4096, 8192, 10240] {
        let b = gpu::throughput_gbs(&g, s, s, false);
        let f = gpu::throughput_gbs(&g, s, s, true);
        t.row(&[
            format!("{s}x{s}"),
            format!("{:.0}/{:.0}", b.load_gbs, b.store_gbs),
            format!("{:.0}/{:.0}", f.load_gbs, f.store_gbs),
            format!("{:+.1}%", (f.store_gbs / b.store_gbs - 1.0) * 100.0),
            format!("{:+.1}%", (f.total_gbs() / b.total_gbs() - 1.0) * 100.0),
        ]);
    }
    t
}

/// Fig. 15: peak device memory (3090 Ti model).
pub fn fig15() -> Table {
    let g = presets::rtx_3090ti_gpu();
    let mut t = Table::new(
        "Fig 15: peak device memory (MB, 3090 Ti model)",
        &["size", "POT", "MAP-UOT", "reduction"],
    );
    for &s in &[1024usize, 2048, 4096, 8192, 10240] {
        let pot = gpu::peak_memory_mb(&g, s, s, false);
        let map = gpu::peak_memory_mb(&g, s, s, true);
        t.row(&[
            format!("{s}x{s}"),
            format!("{pot:.0}"),
            format!("{map:.0}"),
            format!("{:.1}%", (1.0 - map / pot) * 100.0),
        ]);
    }
    t
}

/// Fig. 16: Tianhe-1 scalability (cluster model), M=N=20480.
pub fn fig16() -> Table {
    let mut t = Table::new(
        "Fig 16: Tianhe-1 model, speedup vs POT 1-proc (M=N=20480)",
        &["ppn", "procs", "POT", "COFFEE", "MAP-UOT"],
    );
    const M: usize = 20480;
    for &ppn in &[8usize, 12] {
        let cfg = presets::tianhe1_cluster(ppn);
        let procs: Vec<usize> = match ppn {
            8 => vec![8, 32, 128, 256, 512],
            _ => vec![12, 48, 192, 384, 768],
        };
        for p in procs {
            let s = |k| cluster::speedup_vs_pot1(&cfg, k, M, M, p);
            t.row(&[
                format!("{ppn}"),
                format!("{p}"),
                format!("{:.0}x", s(SolverKind::Pot)),
                format!("{:.0}x", s(SolverKind::Coffee)),
                format!("{:.0}x", s(SolverKind::MapUot)),
            ]);
        }
    }
    t
}

/// Fig. 17: end-to-end color-transfer speedup across solvers.
pub fn fig17() -> (Table, String) {
    let mut t = Table::new(
        "Fig 17: color-transfer end-to-end time (ms) per solver",
        &["image", "palette", "POT", "COFFEE", "MAP-UOT", "vs POT", "vs COFFEE", "uot-only vs POT"],
    );
    // The last row's 8192-color palette makes the plan 268 MB — beyond even
    // this host's 260 MB LLC — so the paper's DRAM-bound regime is measured
    // directly (fewer iterations keep the row affordable; the speedup is
    // per-iteration-cost driven, not budget driven).
    let shapes: &[(usize, usize, usize, usize)] = if fast_mode() {
        &[(96, 64, 128, 100)]
    } else {
        &[
            (480, 320, 256, 300),
            (960, 640, 512, 300),
            (1920, 1280, 1024, 300),
            (1920, 1280, 8192, 24),
        ]
    };
    let mut sps = Vec::new();
    for &(w, h, pal, iters) in shapes {
        let run = |k| {
            let r = apps::color_transfer::run(apps::color_transfer::Config {
                width: w,
                height: h,
                palette: pal,
                solver: k,
                max_iter: iters,
                ..Default::default()
            })
            .report;
            (r.total_s, r.uot_s)
        };
        let (pot, pot_uot) = run(SolverKind::Pot);
        let (cof, _) = run(SolverKind::Coffee);
        let (map, map_uot) = run(SolverKind::MapUot);
        sps.push(pot / map);
        t.row(&[
            format!("{w}x{h}"),
            format!("{pal}"),
            format!("{:.1}", pot * 1e3),
            format!("{:.1}", cof * 1e3),
            format!("{:.1}", map * 1e3),
            format!("{:.2}x", pot / map),
            format!("{:.2}x", cof / map),
            format!("{:.2}x", pot_uot / map_uot),
        ]);
    }
    let s = speedup_summary(&sps);
    (t, s)
}

/// Run every figure (the CLI's `figures` command).
pub fn all() {
    fig02().print();
    fig03().print();
    fig04().print();
    fig05().print();
    let (a, b) = fig08();
    a.print();
    b.print();
    fig08_cpu().print();
    let (t, s) = fig09();
    t.print();
    println!("summary (paper §5.2.1): {s}\n");
    fig10().print();
    fig11().print();
    fig12().print();
    fig12_pool().print();
    let (t, s) = fig13();
    t.print();
    println!("summary (paper §5.3.1): {s}\n");
    fig14().print();
    fig15().print();
    fig16().print();
    let (t, s) = fig17();
    t.print();
    println!("summary (paper §5.5): {s}");
}
