//! Shared bench harness: timing, table formatting, figure row types.
//!
//! `criterion` is not in the offline crate set, so every `benches/fig*.rs`
//! is a `harness = false` binary built on this module: it runs the
//! workload, prints a paper-shaped table, and (where the paper states
//! aggregate claims) a summary row with min / max / geometric mean.

pub mod figures;
pub mod report;

pub use report::Table;

use crate::util::{stats, timer};

/// Default measurement policy for native-solver benches.
#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub warmup: usize,
    pub reps: usize,
}

impl Default for Policy {
    fn default() -> Self {
        Self { warmup: 1, reps: 5 }
    }
}

/// Quick mode: set `MAP_UOT_BENCH_FAST=1` to shrink sizes/reps (CI smoke).
pub fn fast_mode() -> bool {
    std::env::var("MAP_UOT_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Measure median seconds of `f` under `policy`.
pub fn measure<T>(policy: Policy, f: impl FnMut() -> T) -> f64 {
    let samples = timer::sample(policy.warmup, policy.reps, f);
    stats::median(&samples)
}

/// Pretty speedup summary the paper quotes ("up to X, average Y").
pub fn speedup_summary(speedups: &[f64]) -> String {
    format!(
        "up to {:.1}x, avg (geomean) {:.1}x, min {:.1}x over {} points",
        speedups.iter().copied().fold(f64::MIN, f64::max),
        stats::geomean(speedups),
        speedups.iter().copied().fold(f64::MAX, f64::min),
        speedups.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_returns_positive_time() {
        let t = measure(Policy { warmup: 0, reps: 3 }, || {
            std::hint::black_box((0..10_000).sum::<u64>())
        });
        assert!(t >= 0.0);
    }

    #[test]
    fn summary_format() {
        let s = speedup_summary(&[1.0, 2.0, 4.0]);
        assert!(s.contains("up to 4.0x"), "{s}");
        assert!(s.contains("avg (geomean) 2.0x"), "{s}");
    }
}
