//! Artifact manifest: the line-oriented index `aot.py` writes next to the
//! HLO text files (`name file=... kind=... m=... n=... [d=|steps=|block_m=]`).

use std::path::Path;

use crate::error::{Error, Result};

/// What an artifact computes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// `n_steps` fused UOT iterations + marginal error.
    UotChunk,
    /// Gibbs kernel initialization from two point clouds.
    GibbsInit,
    /// Barycentric projection of target points under a plan.
    Barycentric,
}

impl ArtifactKind {
    fn parse(s: &str) -> Result<Self> {
        match s {
            "uot_chunk" => Ok(Self::UotChunk),
            "gibbs_init" => Ok(Self::GibbsInit),
            "barycentric" => Ok(Self::Barycentric),
            other => Err(Error::Artifact(format!("unknown artifact kind {other:?}"))),
        }
    }
}

/// One manifest entry.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: ArtifactKind,
    pub m: usize,
    pub n: usize,
    /// Point dimension (gibbs/barycentric) — 0 for chunks.
    pub d: usize,
    /// Iterations per execution (chunks) — 0 otherwise.
    pub steps: usize,
    /// Pallas panel rows (chunks) — 0 otherwise.
    pub block_m: usize,
}

/// Parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    entries: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Parse manifest text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut entries = Vec::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts
                .next()
                .ok_or_else(|| Error::Artifact(format!("line {}: empty", lineno + 1)))?
                .to_string();
            let mut file = String::new();
            let mut kind = None;
            let (mut m, mut n, mut d, mut steps, mut block_m) = (0, 0, 0, 0, 0);
            for kv in parts {
                let (k, v) = kv.split_once('=').ok_or_else(|| {
                    Error::Artifact(format!("line {}: bad field {kv:?}", lineno + 1))
                })?;
                let int = || -> Result<usize> {
                    v.parse().map_err(|_| {
                        Error::Artifact(format!("line {}: {k}={v:?} not an int", lineno + 1))
                    })
                };
                match k {
                    "file" => file = v.to_string(),
                    "kind" => kind = Some(ArtifactKind::parse(v)?),
                    "m" => m = int()?,
                    "n" => n = int()?,
                    "d" => d = int()?,
                    "steps" => steps = int()?,
                    "block_m" => block_m = int()?,
                    _ => {} // forward-compatible: ignore unknown fields
                }
            }
            let kind = kind
                .ok_or_else(|| Error::Artifact(format!("line {}: missing kind", lineno + 1)))?;
            if file.is_empty() || m == 0 || n == 0 {
                return Err(Error::Artifact(format!("line {}: incomplete entry", lineno + 1)));
            }
            entries.push(ArtifactMeta { name, file, kind, m, n, d, steps, block_m });
        }
        Ok(Self { entries })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Self> {
        Self::parse(&std::fs::read_to_string(path).map_err(|e| {
            Error::Artifact(format!("cannot read {path:?} (run `make artifacts`): {e}"))
        })?)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &ArtifactMeta> {
        self.entries.iter()
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactMeta> {
        self.entries.iter().find(|a| a.name == name)
    }

    /// Exact-match chunk artifact for an `m × n` problem.
    pub fn chunk_exact(&self, m: usize, n: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .find(|a| a.kind == ArtifactKind::UotChunk && a.m == m && a.n == n)
    }

    /// Smallest chunk bucket that fits an `m × n` problem (requests smaller
    /// than a bucket are zero-padded by the router; padding rows/cols carry
    /// zero mass, which the factor guard maps to factor 0, preserving the
    /// solution on the real support).
    pub fn chunk_for(&self, m: usize, n: usize) -> Option<&ArtifactMeta> {
        self.entries
            .iter()
            .filter(|a| a.kind == ArtifactKind::UotChunk && a.m >= m && a.n >= n)
            .min_by_key(|a| a.m * a.n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
uot_chunk_256x256_s8 file=uot_chunk_256x256_s8.hlo.txt kind=uot_chunk m=256 n=256 steps=8 block_m=128
uot_chunk_512x512_s8 file=uot_chunk_512x512_s8.hlo.txt kind=uot_chunk m=512 n=512 steps=8 block_m=64
gibbs_init_256x256x3 file=gibbs_init_256x256x3.hlo.txt kind=gibbs_init m=256 n=256 d=3
";

    #[test]
    fn parses_entries() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 3);
        let c = m.get("uot_chunk_256x256_s8").unwrap();
        assert_eq!(c.kind, ArtifactKind::UotChunk);
        assert_eq!((c.m, c.n, c.steps, c.block_m), (256, 256, 8, 128));
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.chunk_exact(256, 256).unwrap().name, "uot_chunk_256x256_s8");
        assert!(m.chunk_exact(300, 300).is_none());
        // 300x300 pads into the 512 bucket.
        assert_eq!(m.chunk_for(300, 300).unwrap().m, 512);
        // 100x100 pads into the smallest fitting bucket (256).
        assert_eq!(m.chunk_for(100, 100).unwrap().m, 256);
        // too big for any bucket
        assert!(m.chunk_for(2000, 2000).is_none());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("x file=f.hlo kind=bogus m=1 n=1").is_err());
        assert!(Manifest::parse("x kind=uot_chunk m=1 n=1").is_err()); // no file
        assert!(Manifest::parse("x file=f kind=uot_chunk m=zero n=1").is_err());
    }

    #[test]
    fn ignores_unknown_fields() {
        let m = Manifest::parse("a file=f kind=uot_chunk m=4 n=4 future=42").unwrap();
        assert_eq!(m.len(), 1);
    }
}
