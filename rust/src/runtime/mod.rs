//! PJRT runtime: load and execute the AOT artifacts from `artifacts/`.
//!
//! The interchange format is HLO **text** (see `python/compile/aot.py`):
//! jax ≥ 0.5 serializes `HloModuleProto` with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids. Executables
//! are compiled once per artifact and cached; the request path is
//! literal-in / literal-out with no Python anywhere.

pub mod manifest;

pub use manifest::{ArtifactKind, ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::util::Matrix;
// Offline build: the real xla-rs binding is unavailable, so the PJRT
// surface compiles against the in-repo stub (fails fast at `open`).
// Swap this alias for the real crate when the build gains the binding.
use crate::xla_stub as xla;

/// Output of one UOT chunk execution.
#[derive(Debug, Clone, Copy)]
pub struct ChunkOutput {
    /// Marginal L-inf error of the returned plan (device-side reduction).
    pub err: f32,
    /// Iterations advanced (the artifact's compiled-in step count).
    pub steps: usize,
}

/// First device buffer of an execution's `[replica][output]` result, as a
/// typed error instead of a double index (an artifact compiled with no
/// outputs would otherwise panic the service worker).
fn first_output<'b>(bufs: &'b [Vec<xla::PjRtBuffer>], what: &str) -> Result<&'b xla::PjRtBuffer> {
    bufs.first()
        .and_then(|replica| replica.first())
        .ok_or_else(|| Error::Runtime(format!("{what} execution returned no output buffer")))
}

/// A PJRT CPU client plus a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.txt`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, dir, manifest, executables: HashMap::new() })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) the named artifact.
    fn executable(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(name) {
            let meta = self
                .manifest
                .get(name)
                .ok_or_else(|| Error::Artifact(format!("unknown artifact {name:?}")))?;
            let path = self.dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.executables.insert(name.to_string(), exe);
        }
        self.executables
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("artifact {name:?} missing from cache")))
    }

    /// Warm the executable cache for every artifact of `kind`.
    pub fn warmup(&mut self, kind: ArtifactKind) -> Result<usize> {
        let names: Vec<String> = self
            .manifest
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.name.clone())
            .collect();
        for n in &names {
            self.executable(n)?;
        }
        Ok(names.len())
    }

    /// Run one `uot_chunk` artifact in place: advances `plan`/`colsum` by
    /// the artifact's compiled step count and returns the marginal error.
    pub fn run_uot_chunk(
        &mut self,
        plan: &mut Matrix,
        colsum: &mut [f32],
        rpd: &[f32],
        cpd: &[f32],
        fi: f32,
    ) -> Result<ChunkOutput> {
        let (m, n) = (plan.rows(), plan.cols());
        let meta = self
            .manifest
            .chunk_for(m, n)
            .ok_or_else(|| Error::Artifact(format!("no uot_chunk artifact for {m}x{n}")))?
            .clone();
        if (meta.m, meta.n) != (m, n) {
            return Err(Error::Artifact(format!(
                "chunk bucket {}x{} does not match problem {m}x{n} (router must pad first)",
                meta.m, meta.n
            )));
        }
        let steps = meta.steps;
        let exe = self.executable(&meta.name)?;

        let a_lit = xla::Literal::vec1(plan.as_slice()).reshape(&[m as i64, n as i64])?;
        let cs_lit = xla::Literal::vec1(colsum);
        let rpd_lit = xla::Literal::vec1(rpd);
        let cpd_lit = xla::Literal::vec1(cpd);
        let fi_lit = xla::Literal::vec1(&[fi]);

        let bufs = exe.execute::<xla::Literal>(&[a_lit, cs_lit, rpd_lit, cpd_lit, fi_lit])?;
        let result = first_output(&bufs, "uot_chunk")?.to_literal_sync()?;
        let (a_out, cs_out, err_out) = result.to_tuple3()?;

        let a_vec = a_out.to_vec::<f32>()?;
        plan.as_mut_slice().copy_from_slice(&a_vec);
        let cs_vec = cs_out.to_vec::<f32>()?;
        colsum.copy_from_slice(&cs_vec);
        let err = err_out
            .to_vec::<f32>()?
            .first()
            .copied()
            .ok_or_else(|| Error::Runtime("uot_chunk returned an empty err output".into()))?;
        Ok(ChunkOutput { err, steps })
    }

    /// Run a `gibbs_init` artifact: `K = exp(-||x-y||²/eps)` + its colsum.
    pub fn run_gibbs_init(
        &mut self,
        xs: &[f32], // (m, d) row-major
        ys: &[f32], // (n, d) row-major
        m: usize,
        n: usize,
        d: usize,
        eps: f32,
    ) -> Result<(Matrix, Vec<f32>)> {
        let meta = self
            .manifest
            .iter()
            .find(|a| a.kind == ArtifactKind::GibbsInit && a.m == m && a.n == n && a.d == d)
            .ok_or_else(|| Error::Artifact(format!("no gibbs_init artifact for {m}x{n}x{d}")))?
            .clone();
        let exe = self.executable(&meta.name)?;
        let x_lit = xla::Literal::vec1(xs).reshape(&[m as i64, d as i64])?;
        let y_lit = xla::Literal::vec1(ys).reshape(&[n as i64, d as i64])?;
        let eps_lit = xla::Literal::vec1(&[eps]);
        let bufs = exe.execute::<xla::Literal>(&[x_lit, y_lit, eps_lit])?;
        let result = first_output(&bufs, "gibbs_init")?.to_literal_sync()?;
        let (k_out, cs_out) = result.to_tuple2()?;
        let plan = Matrix::from_slice(m, n, &k_out.to_vec::<f32>()?);
        Ok((plan, cs_out.to_vec::<f32>()?))
    }

    /// Run a `barycentric` artifact: map target points under the plan.
    pub fn run_barycentric(&mut self, plan: &Matrix, ys: &[f32], d: usize) -> Result<Vec<f32>> {
        let (m, n) = (plan.rows(), plan.cols());
        let meta = self
            .manifest
            .iter()
            .find(|a| a.kind == ArtifactKind::Barycentric && a.m == m && a.n == n && a.d == d)
            .ok_or_else(|| Error::Artifact(format!("no barycentric artifact for {m}x{n}x{d}")))?
            .clone();
        let exe = self.executable(&meta.name)?;
        let a_lit = xla::Literal::vec1(plan.as_slice()).reshape(&[m as i64, n as i64])?;
        let y_lit = xla::Literal::vec1(ys).reshape(&[n as i64, d as i64])?;
        let bufs = exe.execute::<xla::Literal>(&[a_lit, y_lit])?;
        let result = first_output(&bufs, "barycentric")?.to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("dir", &self.dir)
            .field("artifacts", &self.manifest.len())
            .field("compiled", &self.executables.len())
            .finish()
    }
}
